"""Generate the §Dry-run and §Roofline markdown tables from artifacts.

  PYTHONPATH=src python experiments/build_report.py > experiments/tables.md
"""
from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ART = os.path.join(os.path.dirname(__file__), "artifacts")


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b / 2**30:.2f}"


def dryrun_table() -> str:
    rows = []
    for p in sorted(glob.glob(os.path.join(ART, "dryrun_*.json"))):
        rows.append(json.load(open(p)))
    out = ["| arch | shape | mesh | status | compile_s | temp GiB/dev | "
           "args GiB/dev | AG GiB | AR GiB | A2A GiB |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"{r['status']}: {r.get('reason', r.get('error', ''))[:60]} "
                       f"| - | - | - | - | - | - |")
            continue
        mem = r.get("memory", {})
        cb = r.get("collective_bytes", {})
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{r.get('compile_s', '-')} | "
            f"{fmt_bytes(mem.get('temp_size_in_bytes'))} | "
            f"{fmt_bytes(mem.get('argument_size_in_bytes'))} | "
            f"{fmt_bytes(cb.get('all-gather'))} | "
            f"{fmt_bytes(cb.get('all-reduce'))} | "
            f"{fmt_bytes(cb.get('all-to-all'))} |")
    return "\n".join(out)


def roofline_table(pattern="roofline_*.json", skip_tags=True) -> str:
    from repro.launch.roofline import analyze

    out = ["| arch | shape | compute_s | mem_hlo_s | mem_floor_s | coll_s "
           "| bound | roofline-frac | useful |",
           "|---|---|---|---|---|---|---|---|---|"]
    for p in sorted(glob.glob(os.path.join(ART, pattern))):
        r = json.load(open(p))
        if skip_tags and r.get("tag"):
            continue
        if r.get("status") != "ok":
            continue
        chips = 512 if r["mesh"] == "pod2x16x16" else 256
        a = analyze(r, chips)
        dom = max(a.compute_s, a.memory_floor_s, a.collective_s)
        # roofline fraction: useful-compute time over the dominant term —
        # 1.0 means the dominant resource is fully spent on model math.
        frac = (a.model_flops / (chips * 197e12)) / dom if dom > 0 else 0
        out.append(
            f"| {r['arch']} | {r['shape']} | {a.compute_s:.4f} | "
            f"{a.memory_s:.4f} | {a.memory_floor_s:.4f} | "
            f"{a.collective_s:.4f} | {a.bottleneck} | {frac:.3f} | "
            f"{a.useful_ratio:.3f} |")
    return "\n".join(out)


if __name__ == "__main__":
    print("## Dry-run artifacts\n")
    print(dryrun_table())
    print("\n## Roofline (single-pod, corrected)\n")
    print(roofline_table())
