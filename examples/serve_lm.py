"""Serve a small LM: batched prefill + greedy decode with a KV cache.

  PYTHONPATH=src python examples/serve_lm.py --arch minicpm3-4b --new 48
(minicpm3 exercises the MLA latent cache + absorbed decode.)
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models import model as M
from repro.train.serve_step import greedy_generate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="minicpm3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if cfg.family not in ("dense", "moe"):
        raise SystemExit(f"{args.arch}: serve example targets decoder-only "
                         "LMs (dense/moe)")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    prompt = (jnp.arange(args.batch * args.prompt_len, dtype=jnp.int32)
              .reshape(args.batch, args.prompt_len) * 17) % cfg.vocab_size

    max_seq = args.prompt_len + args.new
    t0 = time.time()
    out = greedy_generate(params, prompt, cfg, max_new=args.new,
                          max_seq=max_seq)
    dt = time.time() - t0
    print(f"{cfg.name}: batch={args.batch} prompt={args.prompt_len} "
          f"new={args.new}")
    print(f"generated shape {out.shape} in {dt:.2f}s "
          f"({args.batch * args.new / dt:.1f} tok/s incl. compile)")
    for b in range(min(args.batch, 2)):
        print(f"  seq{b}: {out[b, :12].tolist()} ...")


if __name__ == "__main__":
    main()
