"""Train an LM (reduced config of any assigned arch) with checkpoints.

Default trains a ~10M-param yi-family model for 300 steps on the synthetic
stream, checkpointing every 100; rerunning the same command auto-resumes.

  PYTHONPATH=src python examples/train_lm.py --arch yi-9b --steps 300
  PYTHONPATH=src python examples/train_lm.py --arch moonshot-v1-16b-a3b
"""
import argparse

from repro.configs import ARCH_IDS, get_config
from repro.launch.train import RunConfig, train_loop
from repro.train.data import DataConfig
from repro.train.optimizer import OptimizerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="yi-9b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    n_params = cfg.param_count()
    print(f"training {cfg.name} ({cfg.family}), ~{n_params / 1e6:.1f}M "
          f"params, {args.steps} steps")
    out = train_loop(
        cfg,
        DataConfig(batch_size=args.batch, seq_len=args.seq,
                   vocab_size=cfg.vocab_size),
        OptimizerConfig(peak_lr=1e-3, warmup_steps=20,
                        total_steps=args.steps),
        RunConfig(steps=args.steps, ckpt_every=100,
                  ckpt_dir=args.ckpt_dir, log_every=20))
    print(f"final loss: {out['final_loss']:.4f} "
          f"(start {out['history'][0]:.4f})")


if __name__ == "__main__":
    main()
