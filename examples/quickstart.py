"""Quickstart: build a synthetic scene, render one frame, save a PPM.

  PYTHONPATH=src python examples/quickstart.py [--out /tmp/frame.ppm]
"""
import argparse

import jax
import numpy as np

from repro.core.camera import look_at, make_camera
from repro.core.pipeline import RenderConfig, render_full_frame
from repro.scenes.synthetic import structured_scene


def save_ppm(path: str, img) -> None:
    arr = (np.clip(np.asarray(img), 0, 1) * 255).astype(np.uint8)
    with open(path, "wb") as f:
        f.write(f"P6\n{arr.shape[1]} {arr.shape[0]}\n255\n".encode())
        f.write(arr.tobytes())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="/tmp/quickstart.ppm")
    ap.add_argument("--size", type=int, default=256)
    ap.add_argument("--gaussians", type=int, default=4000)
    args = ap.parse_args()

    scene = structured_scene(jax.random.PRNGKey(0), args.gaussians,
                             clutter=0.5)
    cam = make_camera(look_at((0.0, -0.5, -3.0), (0.0, 0.0, 6.0)),
                      width=args.size, height=args.size)
    cfg = RenderConfig(intersect_method="tait", capacity=512)
    out, state, rec = jax.jit(render_full_frame,
                              static_argnames="cfg")(scene, cam, cfg=cfg)
    save_ppm(args.out, out.rgb)
    print(f"rendered {args.size}x{args.size} from {args.gaussians} "
          f"gaussians -> {args.out}")
    print(f"  pairs sorted:     {int(rec.sort_pairs.sum())}")
    print(f"  pairs rasterized: {int(rec.raster_pairs.sum())} "
          f"(early stop saved "
          f"{int(rec.sort_pairs.sum()) - int(rec.raster_pairs.sum())})")
    print(f"  mean coverage:    "
          f"{float(1 - out.transmittance.mean()):.3f}")


if __name__ == "__main__":
    main()
