"""Quickstart: build a synthetic scene, render one frame, save a PPM,
then stream a short trajectory through the scanned engine.

  PYTHONPATH=src python examples/quickstart.py [--out /tmp/frame.ppm]
  PYTHONPATH=src python examples/quickstart.py --impl pallas_fused

``--impl`` selects the raster kernel (DESIGN.md §9): ``default`` picks
per backend (fused Pallas kernel on TPU, jnp elsewhere); forcing
``pallas_fused`` off-TPU runs the kernel in interpret mode — slow, but
exactly the CI parity smoke.
"""
import argparse

import jax
import numpy as np

from repro.core.camera import look_at, make_camera
from repro.core.engine import render_trajectory
from repro.core.pipeline import RenderConfig, render_full_frame
from repro.scenes.synthetic import structured_scene
from repro.scenes.trajectory import dolly_trajectory


def save_ppm(path: str, img) -> None:
    arr = (np.clip(np.asarray(img), 0, 1) * 255).astype(np.uint8)
    with open(path, "wb") as f:
        f.write(f"P6\n{arr.shape[1]} {arr.shape[0]}\n255\n".encode())
        f.write(arr.tobytes())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="/tmp/quickstart.ppm")
    ap.add_argument("--size", type=int, default=256)
    ap.add_argument("--gaussians", type=int, default=4000)
    ap.add_argument("--capacity", type=int, default=512,
                    help="K: max sorted pairs per tile")
    from repro.kernels.ops import RASTER_IMPLS, default_impl
    ap.add_argument("--impl", default="default",
                    choices=("default",) + RASTER_IMPLS,
                    help="raster kernel (default: per-backend choice)")
    args = ap.parse_args()

    impl = default_impl() if args.impl == "default" else args.impl

    scene = structured_scene(jax.random.PRNGKey(0), args.gaussians,
                             clutter=0.5)
    cam = make_camera(look_at((0.0, -0.5, -3.0), (0.0, 0.0, 6.0)),
                      width=args.size, height=args.size)
    cfg = RenderConfig(intersect_method="tait", capacity=args.capacity,
                      impl=impl)
    print(f"raster impl: {impl} (backend: {jax.default_backend()})")
    out, state, rec = jax.jit(render_full_frame,
                              static_argnames="cfg")(scene, cam, cfg=cfg)
    save_ppm(args.out, out.rgb)
    print(f"rendered {args.size}x{args.size} from {args.gaussians} "
          f"gaussians -> {args.out}")
    print(f"  pairs sorted:     {int(rec.sort_pairs.sum())}")
    print(f"  pairs rasterized: {int(rec.raster_pairs.sum())} "
          f"(early stop saved "
          f"{int(rec.sort_pairs.sum()) - int(rec.raster_pairs.sum())})")
    print(f"  mean coverage:    "
          f"{float(1 - out.transmittance.mean()):.3f}")

    # Stream a short trajectory: the whole full/sparse loop is ONE
    # compiled lax.scan — no per-frame dispatch from the host.
    n_frames, window = 6, 3
    poses = dolly_trajectory(n_frames, start=(0.0, -0.5, -3.0),
                             target=(0.0, 0.0, 6.0))
    res = render_trajectory(scene, cam, poses,
                            RenderConfig(window=window, impl=impl,
                                         capacity=args.capacity))
    full = np.asarray(res.records.is_full)
    pairs = np.asarray(res.records.raster_pairs).sum(axis=1)
    print(f"\nstreamed {n_frames} frames (window n={window}, one scan):")
    print(f"  schedule:         "
          f"{''.join('F' if f else 's' for f in full)}")
    print(f"  pairs per frame:  {pairs.tolist()}")
    print(f"  sparse-frame cost: "
          f"{pairs[~full].mean() / max(pairs[full].mean(), 1):.2f}x "
          f"of a full frame")


if __name__ == "__main__":
    main()
