"""End-to-end driver: LS-Gaussian streaming rendering over a trajectory.

Renders a 90 FPS camera path with TWSR (window n=5), DPES and TAIT; prints
per-frame quality + workload stats, then runs the accelerator simulator
over the recorded workloads — the full paper pipeline in one script.

  PYTHONPATH=src python examples/streaming_render.py --frames 20
"""
import argparse

import jax
import numpy as np

from repro.core.camera import make_camera
from repro.core.metrics import psnr, ssim
from repro.core.pipeline import RenderConfig, render_full_frame, \
    render_trajectory
from repro.core.streaming import AcceleratorConfig, simulate_sequence, \
    throughput
from repro.scenes.synthetic import structured_scene
from repro.scenes.trajectory import dolly_trajectory


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=20)
    ap.add_argument("--window", type=int, default=5)
    ap.add_argument("--size", type=int, default=192)
    ap.add_argument("--gaussians", type=int, default=3000)
    args = ap.parse_args()

    scene = structured_scene(jax.random.PRNGKey(7), args.gaussians,
                             clutter=0.35)
    cam = make_camera(jax.numpy.eye(4), width=args.size, height=args.size)
    poses = dolly_trajectory(args.frames, start=(0.0, -0.3, -3.0),
                             target=(0.0, 0.0, 6.0))
    cfg = RenderConfig(window=args.window)

    print(f"streaming {args.frames} frames, window n={args.window} "
          f"(1 full render per {args.window} frames)")
    res = render_trajectory(scene, cam, poses, cfg)

    full_fn = jax.jit(render_full_frame, static_argnames="cfg")
    total_pairs_sparse = total_pairs_full = 0
    for f in range(args.frames):
        rec = res.records[f]
        ref, _, _ = full_fn(scene, cam.with_pose(poses[f]), cfg=cfg)
        q = float(psnr(res.frames[f], ref.rgb))
        kind = "FULL  " if bool(rec.is_full) else "sparse"
        total_pairs_sparse += int(rec.raster_pairs.sum())
        total_pairs_full += int(ref.processed_pairs.sum())
        print(f"frame {f:3d} [{kind}] psnr={q:6.2f}dB "
              f"rr_tiles={int(rec.active.sum()):3d} "
              f"interp={int(rec.tiles_interpolated):3d} "
              f"pairs={int(rec.raster_pairs.sum()):6d}")
    print(f"\nrasterized pairs: {total_pairs_sparse} vs always-full "
          f"{total_pairs_full} -> {total_pairs_full / max(total_pairs_sparse, 1):.2f}x reduction")

    # accelerator simulation over the recorded workloads
    from repro.core.streaming import FrameWork
    frames = [FrameWork(
        n_gaussians=int(r.n_gaussians),
        candidate_pairs=int(r.candidate_pairs),
        raw_pairs=np.asarray(r.raw_pairs),
        sort_pairs=np.asarray(r.sort_pairs),
        raster_pairs=np.asarray(r.raster_pairs),
        active=np.asarray(r.active),
        n_warp_pixels=0 if bool(r.is_full) else args.size * args.size,
        tiles_x=cam.tiles_x, tiles_y=cam.tiles_y) for r in res.records]
    acfg = AcceleratorConfig(num_blocks=32)
    gpu = throughput(simulate_sequence(
        frames, acfg, policy="dynamic", workload_source="raw",
        light_to_heavy=False, streaming=False), acfg.num_blocks)
    ls = throughput(simulate_sequence(
        frames, acfg, policy="ls_gaussian", workload_source="dpes",
        light_to_heavy=True, streaming=True), acfg.num_blocks)
    print(f"accelerator sim: {gpu['cycles_per_frame']:.0f} -> "
          f"{ls['cycles_per_frame']:.0f} cycles/frame "
          f"({gpu['cycles_per_frame'] / ls['cycles_per_frame']:.2f}x), "
          f"raster utilization {100 * gpu['utilization']:.0f}% -> "
          f"{100 * ls['utilization']:.0f}%")


if __name__ == "__main__":
    main()
