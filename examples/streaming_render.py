"""End-to-end driver: LS-Gaussian streaming rendering over a trajectory.

Renders a 90 FPS camera path with TWSR (window n=5), DPES and TAIT via the
scanned streaming engine (ONE compiled executable for the whole
trajectory, stacked per-frame records); prints per-frame quality +
workload stats, then runs the accelerator simulator over the recorded
workloads — the full paper pipeline in one script. ``--streams B``
additionally renders B concurrent staggered camera sessions with one
vmapped dispatch (the many-users serving scenario); ``--scenes K``
attaches those streams round-robin over K distinct synthetic scenes
registered in a ``SceneRegistry`` (padded to one bucket, rendered
through the engine's per-slot scene gather — DESIGN.md §10).

  PYTHONPATH=src python examples/streaming_render.py --frames 20
  PYTHONPATH=src python examples/streaming_render.py --streams 4
  PYTHONPATH=src python examples/streaming_render.py --streams 4 --scenes 3
  PYTHONPATH=src python examples/streaming_render.py --impl pallas_fused

``--impl`` selects the raster kernel (DESIGN.md §9); ``default`` picks
the fused Pallas plan-slot kernel on TPU and jnp elsewhere.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.camera import make_camera
from repro.core.engine import render_streams, render_trajectory
from repro.core.metrics import psnr, ssim
from repro.core.pipeline import RenderConfig, render_full_frame
from repro.core.streaming import AcceleratorConfig, frameworks_from_stacked, \
    simulate_sequence, throughput
from repro.scenes.synthetic import structured_scene
from repro.scenes.trajectory import dolly_trajectory


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=20)
    ap.add_argument("--window", type=int, default=5)
    ap.add_argument("--size", type=int, default=192)
    ap.add_argument("--gaussians", type=int, default=3000)
    ap.add_argument("--streams", type=int, default=0,
                    help="also render B concurrent staggered streams")
    ap.add_argument("--scenes", type=int, default=1,
                    help="attach the streams round-robin over K distinct "
                         "scenes (implies --streams >= K)")
    from repro.kernels.ops import RASTER_IMPLS, default_impl
    ap.add_argument("--impl", default="default",
                    choices=("default",) + RASTER_IMPLS,
                    help="raster kernel (default: per-backend choice)")
    args = ap.parse_args()

    impl = default_impl() if args.impl == "default" else args.impl

    scene = structured_scene(jax.random.PRNGKey(7), args.gaussians,
                             clutter=0.35)
    cam = make_camera(jax.numpy.eye(4), width=args.size, height=args.size)
    poses = dolly_trajectory(args.frames, start=(0.0, -0.3, -3.0),
                             target=(0.0, 0.0, 6.0))
    cfg = RenderConfig(window=args.window, impl=impl)
    print(f"raster impl: {impl} (backend: {jax.default_backend()})")

    print(f"streaming {args.frames} frames, window n={args.window} "
          f"(1 full render per {args.window} frames, single lax.scan)")
    res = render_trajectory(scene, cam, poses, cfg)

    # stacked record arrays: one host transfer for the whole trajectory
    is_full = np.asarray(res.records.is_full)
    active = np.asarray(res.records.active).sum(axis=1)
    interp = np.asarray(res.records.tiles_interpolated)
    raster_pairs = np.asarray(res.records.raster_pairs).sum(axis=1)

    full_fn = jax.jit(render_full_frame, static_argnames="cfg")
    total_pairs_full = 0
    for f in range(args.frames):
        ref, _, _ = full_fn(scene, cam.with_pose(poses[f]), cfg=cfg)
        q = float(psnr(res.frames[f], ref.rgb))
        kind = "FULL  " if is_full[f] else "sparse"
        total_pairs_full += int(ref.processed_pairs.sum())
        print(f"frame {f:3d} [{kind}] psnr={q:6.2f}dB "
              f"rr_tiles={int(active[f]):3d} "
              f"interp={int(interp[f]):3d} "
              f"pairs={int(raster_pairs[f]):6d}")
    total_pairs_sparse = int(raster_pairs.sum())
    print(f"\nrasterized pairs: {total_pairs_sparse} vs always-full "
          f"{total_pairs_full} -> {total_pairs_full / max(total_pairs_sparse, 1):.2f}x reduction")

    # accelerator simulation over the recorded workloads
    frames = frameworks_from_stacked(res.records, cam.tiles_x, cam.tiles_y,
                                     args.size * args.size)
    acfg = AcceleratorConfig(num_blocks=32)
    gpu = throughput(simulate_sequence(
        frames, acfg, policy="dynamic", workload_source="raw",
        light_to_heavy=False, streaming=False), acfg.num_blocks)
    ls = throughput(simulate_sequence(
        frames, acfg, policy="ls_gaussian", workload_source="dpes",
        light_to_heavy=True, streaming=True), acfg.num_blocks)
    print(f"accelerator sim: {gpu['cycles_per_frame']:.0f} -> "
          f"{ls['cycles_per_frame']:.0f} cycles/frame "
          f"({gpu['cycles_per_frame'] / ls['cycles_per_frame']:.2f}x), "
          f"raster utilization {100 * gpu['utilization']:.0f}% -> "
          f"{100 * ls['utilization']:.0f}%")

    if args.scenes > 1:
        args.streams = max(args.streams, args.scenes)
    if args.streams > 0:
        b = args.streams
        k = max(args.scenes, 1)
        offsets = np.linspace(0.0, 0.1, b)
        poses_b = jnp.stack([
            dolly_trajectory(args.frames, start=(float(dx), -0.3, -3.0),
                             target=(0.0, 0.0, 6.0)) for dx in offsets])
        if k > 1:
            # Multi-scene serving shape: K same-bucket scenes stacked by
            # a SceneRegistry, streams assigned round-robin, the engine
            # gathering each slot's scene on device (DESIGN.md §10).
            from repro.serve import SceneRegistry
            from repro.serve.scenes import DEFAULT_SCENE_BUCKETS
            # Extend the bucket ladder past --gaussians so any requested
            # scene size registers (a scene is never truncated).
            buckets = list(DEFAULT_SCENE_BUCKETS)
            while buckets[-1] < args.gaussians:
                buckets.append(buckets[-1] * 2)
            registry = SceneRegistry(tuple(buckets))
            registry.register(scene)
            for i in range(1, k):
                registry.register(structured_scene(
                    jax.random.PRNGKey(100 + i), args.gaussians,
                    clutter=0.2 + 0.5 * (i % 3) / 2))
            slot_scene = np.arange(b) % k
            stacked = registry.stack(list(registry.ids()[:k]), b)
            bucket = registry.get(registry.ids()[0]).bucket
            print(f"\nbatched multi-scene serving: {b} streams round-robin "
                  f"over {k} scenes (bucket {bucket}), one vmapped scan")
            print(f"slot -> scene: {slot_scene.tolist()}")
            sres = render_streams(stacked, cam, poses_b, cfg,
                                  slot_scene=slot_scene)
        else:
            print(f"\nbatched serving: {b} concurrent streams, one vmapped "
                  f"scan, staggered key frames")
            sres = render_streams(scene, cam, poses_b, cfg)
        sfull = np.asarray(sres.records.is_full)        # (B, F)
        spairs = np.asarray(sres.records.raster_pairs).sum(axis=2)
        print(f"phases: {np.asarray(sres.phases).tolist()}")
        for f in range(args.frames):
            marks = "".join("F" if sfull[i, f] else "." for i in range(b))
            print(f"step {f:3d} [{marks}] full_renders={int(sfull[:, f].sum())} "
                  f"pairs={int(spairs[:, f].sum()):7d}")
        peak = int(sfull[:, 1:].sum(axis=0).max()) if args.frames > 1 else 0
        print(f"peak concurrent full renders after warmup: {peak} "
              f"(unstaggered would be {b})")


if __name__ == "__main__":
    main()
