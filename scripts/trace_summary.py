#!/usr/bin/env python
"""Summarize a serve-loop Chrome trace (benchmarks/serve_bench.py --trace).

Reads the Chrome-trace JSON a ``StreamServer`` tracer exported and
prints where the wall-clock actually went: total time per span name
(plan / resize / admit / build / dispatch / barrier / commit / compile /
warmup), total time per track (the round track plus one track per
scene-bucket group), and a per-round table (round span duration, frames
dispatched, barrier share). ``--check`` additionally enforces the
observability contract CI relies on — the trace validates
(``repro.obs.trace.validate_chrome_trace``) and records at least one
``compile`` span carrying its executable-cache key.

Usage:
    python scripts/trace_summary.py experiments/artifacts/out.trace.json
    python scripts/trace_summary.py --check out.trace.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from collections import defaultdict

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                os.pardir, "src"))

from repro.obs.trace import validate_chrome_trace  # noqa: E402


def load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def split_events(trace: dict):
    """(track-name map, X events, instant events) from one trace dict."""
    tracks = {}
    for ev in trace["traceEvents"]:
        if ev.get("ph") == "M" and ev["name"] == "thread_name":
            tracks[(ev["pid"], ev["tid"])] = ev["args"]["name"]
    spans = [ev for ev in trace["traceEvents"] if ev.get("ph") == "X"]
    instants = [ev for ev in trace["traceEvents"] if ev.get("ph") == "i"]
    return tracks, spans, instants


def by_name(spans) -> dict:
    """span name -> (count, total ms). 'round' contains the others, so
    the per-name totals deliberately do not sum to the run length."""
    agg = defaultdict(lambda: [0, 0.0])
    for ev in spans:
        agg[ev["name"]][0] += 1
        agg[ev["name"]][1] += ev["dur"] / 1e3
    return {k: (n, ms) for k, (n, ms) in agg.items()}


def by_track(spans, tracks) -> dict:
    """track name -> total ms of its TOP-LEVEL spans (nested spans are
    contained in their parents; counting both would double-bill)."""
    per = defaultdict(list)
    for ev in spans:
        per[tracks.get((ev["pid"], ev["tid"]),
                       str(ev["tid"]))].append(
            (ev["ts"], ev["ts"] + ev["dur"]))
    out = {}
    for track, ivals in per.items():
        ivals.sort()
        total, open_end = 0.0, -1.0
        for t0, t1 in ivals:
            if t0 >= open_end:          # new top-level span
                total += t1 - t0
                open_end = t1
            # else: nested inside the open span — already billed
        out[track] = total / 1e3
    return out


def round_table(spans, tracks):
    """Per-round rows from the round track: duration, frames dispatched
    (summed over that round's dispatch spans), barrier ms."""
    rounds = sorted(
        (ev for ev in spans
         if ev["name"] == "round" and "round" in ev.get("args", {})),
        key=lambda ev: ev["ts"])
    rows = []
    for ev in rounds:
        t0, t1 = ev["ts"], ev["ts"] + ev["dur"]
        inside = [e for e in spans if t0 <= e["ts"] < t1]
        frames = sum(e.get("args", {}).get("frames", 0)
                     for e in inside if e["name"] == "dispatch")
        barrier = sum(e["dur"] for e in inside if e["name"] == "barrier")
        compile_ms = sum(e["dur"] for e in inside
                         if e["name"] == "compile") / 1e3
        rows.append({"round": ev["args"]["round"],
                     "ms": ev["dur"] / 1e3, "frames": frames,
                     "barrier_ms": barrier / 1e3,
                     "compile_ms": compile_ms})
    return rows


def summarize(path: str, check: bool = False) -> int:
    trace = load(path)
    summary = validate_chrome_trace(trace)
    tracks, spans, instants = split_events(trace)
    other = trace.get("otherData", {})

    print(f"{path}: {summary['events']} events "
          f"({summary['spans']} spans, {len(instants)} instants) on "
          f"{summary['tracks']} tracks; dropped={other.get('dropped', 0)}")

    print("\nper span name (ms; 'round' contains the rest):")
    for name, (n, ms) in sorted(by_name(spans).items(),
                                key=lambda kv: -kv[1][1]):
        print(f"  {name:<10} n={n:<5} total={ms:9.2f}")

    print("\nper track (top-level ms):")
    for track, ms in sorted(by_track(spans, tracks).items(),
                            key=lambda kv: -kv[1]):
        print(f"  {track:<24} {ms:9.2f}")

    rows = round_table(spans, tracks)
    if rows:
        print("\nper round:")
        print(f"  {'round':>5} {'ms':>9} {'frames':>6} {'barrier_ms':>10} "
              f"{'compile_ms':>10}")
        for r in rows:
            print(f"  {r['round']:>5} {r['ms']:>9.2f} {r['frames']:>6} "
                  f"{r['barrier_ms']:>10.2f} {r['compile_ms']:>10.2f}")

    if check:
        compiles = [ev for ev in spans if ev["name"] == "compile"]
        if not compiles:
            print("CHECK FAILED: no compile spans recorded", file=sys.stderr)
            return 1
        if not all("key" in ev.get("args", {}) for ev in compiles):
            print("CHECK FAILED: compile span missing its cache key",
                  file=sys.stderr)
            return 1
        if not rows:
            print("CHECK FAILED: no round spans recorded", file=sys.stderr)
            return 1
        print(f"\ncheck ok: {len(compiles)} compile span(s) with keys, "
              f"{len(rows)} round span(s)")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="Chrome-trace JSON from --trace")
    ap.add_argument("--check", action="store_true",
                    help="validate + assert compile/round spans (CI)")
    args = ap.parse_args()
    sys.exit(summarize(args.trace, check=args.check))


if __name__ == "__main__":
    main()
