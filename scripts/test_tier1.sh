#!/usr/bin/env bash
# Tier-1 verification: the fast suite (everything except @pytest.mark.slow).
# Runs in a couple of minutes on CPU; the full suite (tier 2) is plain
# `python -m pytest`. See ROADMAP.md "Testing tiers".
set -euo pipefail
cd "$(dirname "$0")/.."
exec python -m pytest -q -m "not slow" "$@"
