"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see ONE device;
the 512-device override belongs exclusively to launch/dryrun.py."""
import os
import sys

# Make sibling test helpers (tests/_hypothesis_compat.py) importable under
# every pytest import mode.
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import jax
import jax.numpy as jnp
import pytest

from repro.core.camera import make_camera, look_at
from repro.scenes.synthetic import structured_scene, random_blob_scene


@pytest.fixture(scope="session")
def small_scene():
    return structured_scene(jax.random.PRNGKey(7), 600, clutter=0.5)


@pytest.fixture(scope="session")
def blob_scene():
    return random_blob_scene(jax.random.PRNGKey(3), 400)


@pytest.fixture(scope="session")
def small_cam():
    return make_camera(look_at((0.0, -0.3, -2.0), (0.0, 0.0, 6.0)),
                       width=64, height=64)


@pytest.fixture(scope="session")
def wide_cam():
    return make_camera(look_at((0.5, -0.5, -3.0), (0.0, 0.0, 6.0)),
                       width=128, height=96)
