"""Observability contract (DESIGN.md §13): span tracing, the metrics
registry, and — the part that makes tracing safe to ship on — the
observer-effect-zero guarantee: a traced server renders bit-identical
frames through identical executable-cache keys."""
import json
from collections import deque

import jax
import numpy as np
import pytest

from repro.core import engine
from repro.core.pipeline import RenderConfig
from repro.obs import (Counter, Gauge, Histogram, MetricsRegistry,
                       NULL_TRACER, Tracer, validate_chrome_trace)
from repro.scenes.synthetic import structured_scene
from repro.scenes.trajectory import dolly_trajectory
from repro.serve import SceneRegistry, ServeConfig, StreamServer


def _poses(n, dx=0.0):
    return np.asarray(dolly_trajectory(n, start=(dx, -0.3, -2.0),
                                       target=(0.0, 0.0, 6.0)))


# --- tracer unit behavior -------------------------------------------------

def test_disabled_tracer_is_noop():
    tr = Tracer(enabled=False)
    s1 = tr.span("a")
    s2 = tr.span("b", track="other", args={"x": 1})
    assert s1 is s2                       # shared null span: no allocation
    with s1:
        pass
    tr.instant("mark")
    assert tr.events() == [] and tr.dropped == 0
    assert NULL_TRACER.span("c") is s1


def test_tracer_records_spans_and_instants():
    tr = Tracer(enabled=True)
    with tr.span("outer", track="round", args={"round": 1}):
        with tr.span("inner", track="round"):
            pass
    tr.instant("resize", track="bucket (512, 4)", args={"to": 4})
    evs = tr.events()
    assert [e["name"] for e in evs] == ["inner", "outer", "resize"]
    inner, outer, inst = evs
    # children exit (and append) before parents; nesting is by ts/dur
    assert outer["ph"] == "X" and inner["ph"] == "X" and inst["ph"] == "i"
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-9
    assert outer["args"] == {"round": 1}
    # distinct tracks get distinct tids
    assert inner["tid"] == outer["tid"] != inst["tid"]
    chrome = tr.to_chrome()
    assert validate_chrome_trace(chrome)["spans"] == 2
    names = {ev["args"]["name"] for ev in chrome["traceEvents"]
             if ev["ph"] == "M" and ev["name"] == "thread_name"}
    assert names == {"round", "bucket (512, 4)"}


def test_tracer_buffer_bounded_keeps_first():
    tr = Tracer(enabled=True, keep=8)
    for i in range(20):
        with tr.span(f"s{i}"):
            pass
    evs = tr.events()
    assert len(evs) == 8 and tr.dropped == 12
    assert [e["name"] for e in evs] == [f"s{i}" for i in range(8)]
    chrome = tr.to_chrome()
    assert chrome["otherData"] == {"events": 8, "dropped": 12}
    validate_chrome_trace(chrome)         # truncation stays well-formed


def test_tracer_write_roundtrip(tmp_path):
    tr = Tracer(enabled=True)
    with tr.span("round", track="round"):
        pass
    path = tmp_path / "t.trace.json"
    assert tr.write(str(path)) == 1
    trace = json.loads(path.read_text())
    summary = validate_chrome_trace(trace)
    assert summary["spans"] == 1 and summary["names"] == ["round"]
    names = {ev["args"]["name"] for ev in trace["traceEvents"]
             if ev["ph"] == "M" and ev["name"] == "thread_name"}
    assert names == {"round"}


def test_validate_rejects_malformed():
    ok = {"traceEvents": [{"name": "a", "ph": "X", "ts": 0.0, "dur": 1.0,
                           "pid": 1, "tid": 0}]}
    assert validate_chrome_trace(ok)["spans"] == 1
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": "nope"})
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": [
            {"name": "a", "ph": "X", "ts": 0.0, "pid": 1, "tid": 0}]})
    with pytest.raises(ValueError):       # negative dur
        validate_chrome_trace({"traceEvents": [
            {"name": "a", "ph": "X", "ts": 0.0, "dur": -1.0, "pid": 1,
             "tid": 0}]})
    with pytest.raises(ValueError):       # overlap without nesting
        validate_chrome_trace({"traceEvents": [
            {"name": "a", "ph": "X", "ts": 0.0, "dur": 2.0, "pid": 1,
             "tid": 0},
            {"name": "b", "ph": "X", "ts": 1.0, "dur": 2.0, "pid": 1,
             "tid": 0}]})


# --- metrics registry -----------------------------------------------------

def test_counter_gauge_semantics():
    reg = MetricsRegistry()
    c = reg.counter("frames_total", "help text")
    c.inc()
    c.inc(3)
    assert c.value == 4
    with pytest.raises(ValueError):
        c.inc(-1)
    assert reg.counter("frames_total") is c   # get-or-create identity
    g = reg.gauge("peak")
    g.set_max(5)
    g.set_max(3)
    assert g.value == 5
    g.set(2)
    assert g.value == 2
    with pytest.raises(TypeError):            # kind mismatch
        reg.gauge("frames_total")


def test_labeled_metrics_are_distinct():
    reg = MetricsRegistry()
    a = reg.counter("served", bucket="(256, 4)")
    b = reg.counter("served", bucket="(512, 4)")
    a.inc()
    assert b.value == 0
    assert a.key == 'served{bucket="(256, 4)"}'
    snap = reg.snapshot()
    assert snap["counters"][a.key] == 1
    assert snap["counters"][b.key] == 0


def test_histogram_empty_is_none_never_nan():
    h = MetricsRegistry().histogram("lat")
    assert h.percentile(50) is None
    st = h.stats()
    assert st == {"count": 0, "sum": 0.0, "min": None, "max": None,
                  "kept": 0, "p50": None, "p90": None, "p99": None}
    json.dumps(st)                            # and JSON-safe


def test_histogram_reservoir_bounded_lifetime_exact():
    reg = MetricsRegistry()
    h = reg.histogram("work", keep=4)
    h.observe_many(range(10))                 # 0..9
    h.observe_many([])                        # no-op, never raises
    assert h.count == 10 and h.total == 45.0
    assert (h.vmin, h.vmax) == (0.0, 9.0)     # lifetime, not reservoir
    assert h.values() == [6.0, 7.0, 8.0, 9.0]  # newest-keep window
    st = h.stats()
    assert st["kept"] == 4 and st["p50"] == 7.5


def test_prometheus_exposition():
    reg = MetricsRegistry()
    reg.counter("serve_frames_total", "frames").inc(7)
    reg.gauge("peak", bucket="(256, 4)").set(3)
    reg.histogram("lat").observe(0.5)
    reg.histogram("empty_lat")
    text = reg.to_prometheus()
    assert "# TYPE serve_frames_total counter" in text
    assert "serve_frames_total 7" in text
    assert 'peak{bucket="(256, 4)"} 3' in text
    assert 'lat{quantile="0.5"} 0.5' in text
    assert "lat_count 1" in text
    # empty histogram: no quantile rows, but count/sum still exported
    assert 'empty_lat{quantile' not in text
    assert "empty_lat_count 0" in text


# --- server integration ---------------------------------------------------

def _server(small_cam, trace: bool, **kw):
    reg = SceneRegistry((256, 512))
    entry = reg.register(structured_scene(jax.random.PRNGKey(9), 260,
                                          clutter=0.4))
    cfg = RenderConfig(window=3, capacity=128, rerender_capacity=8)
    scfg = ServeConfig(slots=2, chunk=2, r_buckets=(8,),
                       scene_buckets=(256, 512), trace=trace, **kw)
    return StreamServer(reg, small_cam, cfg, scfg), entry


def test_tracing_observer_effect_zero(small_cam):
    """Tracing ON and OFF: bit-identical frames, identical cache keys.

    The tracer only times host phases and the annotate() scopes only
    rename ops — neither may perturb numerics or the executable family.
    """
    frames, keys = {}, {}
    for trace in (False, True):
        srv, entry = _server(small_cam, trace, collect_frames=True)
        sessions = [srv.attach(_poses(5, dx=0.05 * i),
                               scene_id=entry.scene_id)
                    for i in range(2)]
        report = srv.run(max_rounds=20)
        assert report["streams_finished"] == 2
        frames[trace] = [np.concatenate(s.frames) for s in sessions]
        keys[trace] = sorted(report["cache"]["keys"])
    assert keys[False] == keys[True]
    for a, b in zip(frames[False], frames[True]):
        np.testing.assert_array_equal(a, b)


def test_traced_server_exports_valid_trace(small_cam, tmp_path):
    srv, entry = _server(small_cam, True, sim_latency=True)
    srv.attach(_poses(4), scene_id=entry.scene_id)
    srv.run(max_rounds=20)
    path = tmp_path / "serve.trace.json"
    srv.tracer.write(str(path))
    summary = validate_chrome_trace(json.loads(path.read_text()))
    for name in ("round", "plan", "dispatch", "barrier", "commit",
                 "compile"):
        assert name in summary["names"]
    compiles = [ev for ev in srv.tracer.events()
                if ev["name"] == "compile"]
    assert compiles and all("key" in ev["args"] for ev in compiles)
    # the cache's split agrees: the compiled key billed compile once and
    # dispatched cheaper thereafter
    timing = srv.cache.stats()["per_key_timing"]
    compiled = [t for t in timing.values() if t["compile_ms"] is not None]
    assert compiled and compiled[0]["dispatch_calls"] >= 1


def test_trace_buffer_bounded_under_serving(small_cam):
    srv, entry = _server(small_cam, True, trace_keep=8)
    srv.attach(_poses(6), scene_id=entry.scene_id)
    srv.run(max_rounds=20)
    assert len(srv.tracer.events()) == 8 and srv.tracer.dropped > 0
    validate_chrome_trace(srv.tracer.to_chrome())


def test_report_before_first_round_is_clean(small_cam):
    """Empty reservoirs must report None — never NaN, never raise —
    including per-bucket entries for buckets that never rendered."""
    srv, _ = _server(small_cam, True, sim_latency=True)
    report = srv.report()
    json.dumps(report)                        # fully serializable
    assert report["latency_p50_ms"] is None
    assert report["latency_p99_ms"] is None
    assert report["frames_per_second"] is None
    assert report["sim"] is None
    assert report["rounds_trace_dropped"] == 0
    pb = report["per_bucket"]["(512, 4)"]     # batcher exists, 0 frames
    assert pb["frames"] == 0
    assert pb["latency_p50_ms"] is None and pb["latency_p99_ms"] is None
    hists = report["metrics"]["histograms"]
    assert hists["serve_latency_seconds"]["p50"] is None


def test_rounds_trace_bound_is_counted(small_cam):
    srv, entry = _server(small_cam, False)
    srv.trace = deque(maxlen=1)               # worst-case bound
    srv.attach(_poses(6), scene_id=entry.scene_id)
    report = srv.run(max_rounds=20)
    assert len(report["rounds_trace"]) == 1
    assert report["rounds_trace_dropped"] >= 1
    assert report["rounds_trace_dropped"] == report["rounds"] - 1
    # and the counter rode the shared registry
    assert report["metrics"]["counters"][
        "serve_rounds_trace_dropped_total"] == report["rounds_trace_dropped"]


def test_frame_parity_across_chunks_with_tracing(small_cam):
    """Traced frames equal the solo engine render (the collect_frames
    parity pattern), so spans cost nothing in numerics even across
    chunk seams."""
    srv, entry = _server(small_cam, True, collect_frames=True)
    sess = srv.attach(_poses(5), scene_id=entry.scene_id)
    srv.run(max_rounds=20)
    got = np.concatenate(sess.frames)
    solo = engine.render_trajectory(
        entry.scene, small_cam, jax.numpy.asarray(_poses(5)),
        RenderConfig(window=3, capacity=128, rerender_capacity=8),
        phase=sess.phase)
    np.testing.assert_allclose(got, np.asarray(solo.frames), atol=1e-5)
