"""Accelerator-simulator invariants (core/streaming.py) on the ablation
workloads: streaming never loses to a frame barrier, utilization stays a
valid fraction, and light-to-heavy ordering never hurts sort stalls."""
import numpy as np
import pytest

from repro.core.streaming import (AcceleratorConfig, FrameWork,
                                  frameworks_from_stacked,
                                  simulate_sequence, throughput)

# The benchmark ablation ladder (benchmarks/accelerator.py MODES).
MODES = {
    "gpu_like": dict(policy="dynamic", workload_source="raw",
                     light_to_heavy=False),
    "gscore_like": dict(policy="round_robin", workload_source="raw",
                        light_to_heavy=False),
    "ld1": dict(policy="ls_gaussian", workload_source="dpes",
                light_to_heavy=False),
    "ls_gaussian": dict(policy="ls_gaussian", workload_source="dpes",
                        light_to_heavy=True),
}


def _ablation_frames(seed, n_frames=6, t=256, heavy_frac=0.08,
                     sparse_every=0):
    """Fig. 5-style order-of-magnitude tile-load spread; optionally every
    ``sparse_every``-th frame is TWSR-sparse (inactive tiles + warp)."""
    rng = np.random.default_rng(seed)
    frames = []
    for f in range(n_frames):
        w = rng.integers(20, 80, size=t).astype(np.int64)
        heavy = rng.choice(t, int(t * heavy_frac), replace=False)
        w[heavy] = rng.integers(300, 700, size=len(heavy))
        active = np.ones(t, bool)
        warp_px = 0
        if sparse_every and f % sparse_every != 0:
            active = rng.random(t) < 0.3
            w = np.where(active, w, 0)
            warp_px = t * 256
        frames.append(FrameWork(
            n_gaussians=2000, candidate_pairs=int(w.sum() * 1.2),
            raw_pairs=w * 2, sort_pairs=w, raster_pairs=w, active=active,
            n_warp_pixels=warp_px, tiles_x=16, tiles_y=16))
    return frames


def _wall_span(timings):
    return max(t.frame_end for t in timings)


@pytest.mark.parametrize("mode", sorted(MODES))
@pytest.mark.parametrize("sparse_every", [0, 3])
def test_streaming_never_slower(mode, sparse_every):
    """Removing the global frame barrier can only overlap work: the wall
    span of the sequence must never grow."""
    frames = _ablation_frames(7, sparse_every=sparse_every)
    cfg = AcceleratorConfig(num_blocks=32)
    kw = MODES[mode]
    stream = simulate_sequence(frames, cfg, streaming=True, **kw)
    barrier = simulate_sequence(frames, cfg, streaming=False, **kw)
    assert _wall_span(stream) <= _wall_span(barrier) + 1e-6, mode


@pytest.mark.parametrize("mode", sorted(MODES))
@pytest.mark.parametrize("streaming", [True, False])
def test_utilization_bounds(mode, streaming):
    frames = _ablation_frames(11, sparse_every=3)
    cfg = AcceleratorConfig(num_blocks=32)
    timings = simulate_sequence(frames, cfg, streaming=streaming,
                                **MODES[mode])
    t = throughput(timings, cfg.num_blocks)
    assert 0.0 < t["utilization"] <= 1.0 + 1e-9, (mode, t["utilization"])
    for ft in timings:
        assert 0.0 < ft.utilization <= 1.0 + 1e-9
        assert ft.frame_end >= ft.prep_end


@pytest.mark.parametrize("seed", [3, 13, 23])
@pytest.mark.parametrize("gsu_rate", [2.0, 8.0, 64.0])
def test_light_to_heavy_never_increases_sort_stall(seed, gsu_rate):
    """LD2's whole point: serving light tiles first can only shrink the
    time blocks spend waiting on the shared sorter."""
    frames = _ablation_frames(seed)
    cfg = AcceleratorConfig(num_blocks=32, gsu_rate=gsu_rate)
    with_ld2 = throughput(simulate_sequence(
        frames, cfg, policy="ls_gaussian", workload_source="dpes",
        light_to_heavy=True), cfg.num_blocks)
    without = throughput(simulate_sequence(
        frames, cfg, policy="ls_gaussian", workload_source="dpes",
        light_to_heavy=False), cfg.num_blocks)
    assert with_ld2["sort_stall"] <= without["sort_stall"] + 1e-6


def test_invariants_on_real_records(small_scene, small_cam):
    """The same invariants hold on records from the real scanned pipeline
    (stacked-record ingestion path)."""
    from repro.core.engine import render_trajectory
    from repro.core.pipeline import RenderConfig
    from repro.scenes.trajectory import dolly_trajectory

    poses = dolly_trajectory(4, start=(0.0, -0.3, -2.0),
                             target=(0.0, 0.0, 6.0))
    res = render_trajectory(small_scene, small_cam, poses,
                            RenderConfig(window=2))
    frames = frameworks_from_stacked(
        res.records, small_cam.tiles_x, small_cam.tiles_y,
        small_cam.width * small_cam.height)
    assert len(frames) == 4
    assert frames[0].n_warp_pixels == 0          # full frame: no VTU work
    assert frames[1].n_warp_pixels > 0           # sparse frame warps
    cfg = AcceleratorConfig(num_blocks=8)
    for mode, kw in MODES.items():
        stream = simulate_sequence(frames, cfg, streaming=True, **kw)
        barrier = simulate_sequence(frames, cfg, streaming=False, **kw)
        assert _wall_span(stream) <= _wall_span(barrier) + 1e-6, mode
        t = throughput(stream, cfg.num_blocks)
        assert 0.0 < t["utilization"] <= 1.0 + 1e-9, mode
