"""Multi-scene serving (DESIGN.md §10): scene-bucket padding parity, the
registry lifecycle, the (B, R) bucket policy, scene-aware slot packing,
the engine's slot_scene gather vs solo renders, and end-to-end server
parity across scene mixing, chunk seams, and an elastic-B resize."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.core.pipeline import RenderConfig, render_full_frame
from repro.scenes.synthetic import random_blob_scene, structured_scene
from repro.scenes.trajectory import dolly_trajectory
from repro.serve import (AdmissionConfig, BucketPolicy, ContinuousBatcher,
                         SceneRegistry, ServeConfig, SessionManager,
                         StreamServer, pad_scene, snap_scene_bucket,
                         suggest_buckets)

_RECORD_FIELDS = ("is_full", "n_gaussians", "candidate_pairs", "raw_pairs",
                  "sort_pairs", "raster_pairs", "active",
                  "tiles_interpolated", "overflow_pairs", "overflow_tiles",
                  "block_of_tile", "order_in_block", "block_load")


def _poses(n, dx=0.0):
    return dolly_trajectory(n, start=(dx, -0.3, -2.0),
                            target=(0.0, 0.0, 6.0))


def _scenes(k, n=260, n_step=30):
    """k distinct same-bucket structured scenes (bucket 512 for the
    defaults: 260..260+30k Gaussians, SH degree 1)."""
    return [structured_scene(jax.random.PRNGKey(100 + i), n + n_step * i,
                             clutter=0.3 + 0.1 * i) for i in range(k)]


# --- scene-bucket padding (must be exact, not approximate) ----------------

def test_snap_scene_bucket():
    assert snap_scene_bucket(3, (256, 512)) == 256
    assert snap_scene_bucket(256, (256, 512)) == 256
    assert snap_scene_bucket(257, (256, 512)) == 512
    with pytest.raises(ValueError):
        snap_scene_bucket(513, (256, 512))      # scenes never truncate
    with pytest.raises(ValueError):
        snap_scene_bucket(10, (512, 256))       # buckets must ascend


def test_pad_scene_renders_bit_identical(small_scene, small_cam):
    """Padding Gaussians are invalid for every pose (opacity cull), so
    the padded scene is bit-identical in frames AND records — including
    n_gaussians, pair counts, and the LDU schedule."""
    padded = pad_scene(small_scene, 1024)
    assert padded.num_gaussians == 1024
    cfg = RenderConfig(capacity=128)
    fn = jax.jit(render_full_frame, static_argnames="cfg")
    out_p, _, rec_p = fn(padded, small_cam, cfg=cfg)
    out_o, _, rec_o = fn(small_scene, small_cam, cfg=cfg)
    np.testing.assert_array_equal(np.asarray(out_p.rgb),
                                  np.asarray(out_o.rgb))
    for name in _RECORD_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(rec_p, name)),
            np.asarray(getattr(rec_o, name)), err_msg=name)
    with pytest.raises(ValueError):
        pad_scene(small_scene, small_scene.num_gaussians - 1)


def test_pad_scene_contrib_parity(small_scene, small_cam):
    """The contribution statistics obey the same padding contract: the
    padded scene's per-lane contributions are bit-identical, its
    per-Gaussian prior matches on the real prefix, and every padding
    Gaussian reads as never-considered (inf = keep-all)."""
    n = small_scene.num_gaussians
    padded = pad_scene(small_scene, 1024)
    cfg = RenderConfig(capacity=128, record_contrib=True)
    fn = jax.jit(render_full_frame, static_argnames="cfg")
    _, st_p, rec_p = fn(padded, small_cam, cfg=cfg)
    _, st_o, rec_o = fn(small_scene, small_cam, cfg=cfg)
    np.testing.assert_array_equal(np.asarray(rec_p.lane_contrib),
                                  np.asarray(rec_o.lane_contrib))
    prior_p = np.asarray(st_p.contrib)
    prior_o = np.asarray(st_o.contrib)
    assert prior_p.shape == (1024,)
    np.testing.assert_array_equal(prior_p[:n], prior_o)
    assert np.all(np.isinf(prior_p[n:]))


# --- registry lifecycle ---------------------------------------------------

def test_registry_register_evict_refs():
    reg = SceneRegistry((256, 512))
    e0 = reg.register(_scenes(1)[0])            # 260 -> bucket 512
    e1 = reg.register(random_blob_scene(jax.random.PRNGKey(1), 100))
    assert e0.bucket == (512, 4) and e1.bucket == (256, 1)
    assert reg.ids() == (0, 1) and len(reg) == 2
    assert reg.by_bucket((512, 4)) == [0]
    assert reg.buckets_in_use() == ((256, 1), (512, 4))

    reg.acquire(e0.scene_id)
    with pytest.raises(ValueError):
        reg.evict(e0.scene_id)                  # pinned by a live stream
    reg.release(e0.scene_id)
    reg.evict(e0.scene_id)
    assert e0.scene_id not in reg and len(reg) == 1
    with pytest.raises(KeyError):
        reg.get(e0.scene_id)
    with pytest.raises(ValueError):
        reg.release(e1.scene_id)                # never acquired


def test_registry_stack_rules():
    reg = SceneRegistry((256, 512))
    a, b = (reg.register(s) for s in _scenes(2))
    blob = reg.register(random_blob_scene(jax.random.PRNGKey(2), 80))
    stack = reg.stack([a.scene_id, b.scene_id], 4)
    assert stack.means.shape == (4, 512, 3)     # padded to size w/ repeats
    np.testing.assert_array_equal(np.asarray(stack.means[2]),
                                  np.asarray(stack.means[0]))
    with pytest.raises(ValueError):
        reg.stack([a.scene_id, blob.scene_id], 4)   # bucket mismatch
    with pytest.raises(ValueError):
        reg.stack([a.scene_id, b.scene_id], 1)      # does not fit
    with pytest.raises(ValueError):
        reg.stack([], 2)


# --- the 2-axis (B, R) bucket policy --------------------------------------

def test_bucket_policy_picks():
    pol = BucketPolicy(b_buckets=(2, 4, 8), r_buckets=(4, 16))
    assert pol.max_keys == 6
    assert pol.pick_slots(0) == 2               # empty queue: smallest B
    assert pol.pick_slots(2) == 2
    assert pol.pick_slots(3) == 4
    assert pol.pick_slots(100) == 8             # flood: largest B caps
    assert pol.pick_capacity([]) == 4           # nothing observed yet
    assert pol.pick_capacity([3, 3, 3, 20]) == 16
    assert pol.pick(5, [2, 2]) == (8, 4)
    with pytest.raises(ValueError):
        BucketPolicy(b_buckets=(4, 2))
    with pytest.raises(ValueError):
        BucketPolicy(quantile=1.5)


def test_suggest_buckets_from_records():
    from types import SimpleNamespace
    t = 16
    active = np.zeros((6, t), bool)
    active[:, :2] = True
    recs = SimpleNamespace(active=active, overflow_tiles=np.full((6,), 8),
                           is_full=np.zeros((6,), bool))
    pol = BucketPolicy(b_buckets=(2, 4), r_buckets=(4, 16, 32))
    assert suggest_buckets(recs, queue_depth=3, policy=pol) == (4, 16)


# --- scene-aware slot packing + elastic resize ----------------------------

def test_batcher_packs_same_scene_groups(small_cam):
    """With group=2 over B=4 slots, same-scene streams co-locate into
    contiguous groups regardless of arrival interleaving."""
    m = SessionManager(window=4)
    bat = ContinuousBatcher(slots=4, chunk=2, cam=small_cam, group=2)
    eye = np.eye(4, dtype=np.float32)
    order = [10, 20, 10, 20]                    # interleaved scene ids
    sessions = [m.attach(np.stack([eye] * 2), scene_id=s) for s in order]
    assert bat.admit(m) == 4
    batch = bat.build(m)
    by_slot = [m.sessions[sid].scene_id for sid in batch.sids]
    assert by_slot == [10, 10, 20, 20]          # grouped, not interleaved
    # slot_scene indexes the round's distinct scene_ids
    assert batch.scene_ids == (10, 20)
    assert np.asarray(batch.slot_scene).tolist() == [0, 0, 1, 1]
    assert sessions[0].slot == 0                # oldest kept its group


def test_batcher_admit_allowed_filter(small_cam):
    m = SessionManager(window=4)
    bat = ContinuousBatcher(slots=2, chunk=2, cam=small_cam)
    eye = np.eye(4, dtype=np.float32)
    s_a = m.attach(np.stack([eye] * 2), scene_id=1)
    s_b = m.attach(np.stack([eye] * 2), scene_id=2)
    assert bat.admit(m, allowed={2}) == 1       # bucket rule: only scene 2
    assert s_a.slot is None and s_b.slot == 0


def test_batcher_resize_preserves_carries(small_cam):
    m = SessionManager(window=4)
    bat = ContinuousBatcher(slots=3, chunk=2, cam=small_cam)
    eye = np.eye(4, dtype=np.float32)
    sessions = [m.attach(np.stack([eye] * 4), scene_id=0) for _ in range(3)]
    bat.admit(m)
    carry = engine.init_carry(small_cam, eye)
    for s in sessions:
        s.carry = carry
    unbound = bat.resize(2, m)
    assert unbound == [sessions[2].sid]
    assert bat.slots == 2 and sessions[2].slot is None
    assert sessions[2].carry is carry           # carry untouched by unbind
    assert [s.sid for s in m.waiting()] == [sessions[2].sid]
    bat.resize(4, m)
    assert bat.slots == 4 and bat.admit(m) == 1  # rebinds the unbound one
    assert bat.empty_batch().poses.shape == (4, 2, 4, 4)
    assert bat.empty_batch(slots=2).counts.shape == (2,)


# --- slot_scene gather parity vs solo renders -----------------------------

def test_multi_scene_streams_match_solo(small_cam):
    """Streams attached to DIFFERENT scenes through the stacked
    slot_scene gather bit-match their solo single-scene renders (records
    exact, frames to float tolerance) across phases and ragged counts;
    masked slots (scene 0) stay blank."""
    reg = SceneRegistry((256, 512))
    entries = [reg.register(s) for s in _scenes(2)]
    cfg = RenderConfig(window=3, rerender_capacity=8, capacity=128)
    b, f = 4, 5
    slot_scene = (0, 1, 1, 0)
    counts = (5, 4, 3, 0)
    phases = (0, 1, 2, 0)
    poses = jnp.stack([_poses(f, dx=0.04 * i) for i in range(b)])
    stack = reg.stack([e.scene_id for e in entries], b)
    res = engine.render_streams(stack, small_cam, poses, cfg,
                                phases=phases, counts=counts,
                                slot_scene=slot_scene)
    for i in range(b):
        if counts[i] == 0:
            np.testing.assert_array_equal(np.asarray(res.frames[i]), 0.0)
            continue
        solo = engine.render_trajectory(entries[slot_scene[i]].scene,
                                        small_cam, poses[i], cfg,
                                        phase=phases[i])
        c = counts[i]
        np.testing.assert_allclose(np.asarray(res.frames[i][:c]),
                                   np.asarray(solo.frames[:c]), atol=1e-5)
        for name in _RECORD_FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(res.records, name))[i, :c],
                np.asarray(getattr(solo.records, name))[:c],
                err_msg=f"slot{i}:{name}")


# --- end-to-end: scene mixing + chunk seams + a B-resize event ------------

def test_server_multi_scene_resize_parity(small_cam):
    """Four streams over two scenes served through elastic-B rounds
    (including a forced shrink/grow resize mid-flight) reproduce their
    solo trajectories: chunk seams, slot unbinding, and scene stacking
    all preserve the carries bit-exactly (frames to float tolerance)."""
    reg = SceneRegistry((256, 512))
    entries = [reg.register(s) for s in _scenes(2)]
    # One R bucket so the solo reference can pin the same
    # rerender_capacity (an adapting R mid-trajectory has no solo
    # equivalent — that axis is covered by test_serve's demand tests).
    cfg = RenderConfig(window=3, capacity=128, rerender_capacity=8)
    scfg = ServeConfig(chunk=2, r_buckets=(8,), b_buckets=(2, 4),
                       adapt_every=2, collect_frames=True,
                       scene_buckets=(256, 512))
    srv = StreamServer(reg, small_cam, cfg, scfg)

    total = 7
    sessions = []
    for i in range(4):
        sessions.append(srv.attach(
            np.asarray(_poses(total, dx=0.05 * i)),
            scene_id=entries[i % 2].scene_id))
    # queue depth 4 -> first busy round resizes 2 -> 4
    assert srv.batcher.slots == 2
    srv.step()
    assert srv.batcher.slots == 4 and srv.slots_history == [2, 4]

    # force a shrink mid-flight: detach-eligible streams drain at
    # different times because chunk=2 over 7 frames staggers by arrival;
    # keep stepping until everything drained (max_rounds bounds it).
    report = srv.run(max_rounds=30)
    assert report["streams_finished"] == 4
    assert not srv.manager.sessions and srv.batcher.bound == 0
    assert len(set(report["slots_history"])) >= 2   # a resize was served

    for i, sess in enumerate(sessions):
        got = np.concatenate(sess.frames)
        assert got.shape[0] == total
        solo = engine.render_trajectory(entries[i % 2].scene, small_cam,
                                        jnp.asarray(_poses(total,
                                                           dx=0.05 * i)),
                                        cfg, phase=sess.phase)
        np.testing.assert_allclose(got, np.asarray(solo.frames), atol=1e-5)

    # every scene's refcount released; eviction now legal
    for e in entries:
        assert reg.get(e.scene_id).refs == 0
        srv.evict_scene(e.scene_id)
    assert len(reg) == 0


def test_server_detach_releases_scene_pin(small_cam):
    """Cancelling via the server (not bare manager.detach) drops the
    scene refcount, so eviction stays possible after cancellations."""
    reg = SceneRegistry((256, 512))
    entry = reg.register(_scenes(1)[0])
    srv = StreamServer(reg, small_cam,
                       RenderConfig(window=3, capacity=128),
                       ServeConfig(slots=2, chunk=2, r_buckets=(8,),
                                   scene_buckets=(256, 512)))
    sess = srv.attach(np.asarray(_poses(4)), scene_id=entry.scene_id)
    assert reg.get(entry.scene_id).refs == 1
    srv.detach(sess.sid)
    assert reg.get(entry.scene_id).refs == 0
    srv.evict_scene(entry.scene_id)     # no longer pinned
    assert len(reg) == 0


@pytest.mark.slow
def test_sharded_multi_scene_matches_single_device():
    """8 slots over 8 host devices with 4 distinct scenes and contiguous
    scene groups of B/D slots (local B=1 -> per-device scene gather +
    real lax.cond): frames within 1e-5 and records bit-exact vs the
    plain single-logical-batch slot_scene path."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(repo, "src"), JAX_PLATFORMS="cpu")
    script = textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import engine
        from repro.core.camera import make_camera, look_at
        from repro.core.pipeline import RenderConfig
        from repro.scenes.synthetic import structured_scene
        from repro.scenes.trajectory import dolly_trajectory
        from repro.serve import SceneRegistry, build_render_fn, stream_mesh

        reg = SceneRegistry((256, 512))
        ids = [reg.register(structured_scene(
            jax.random.PRNGKey(50 + i), 260 + 20 * i,
            clutter=0.4 + 0.1 * i)).scene_id for i in range(4)]
        cam = make_camera(look_at((0.0, -0.3, -2.0), (0.0, 0.0, 6.0)),
                          width=48, height=48)
        cfg = RenderConfig(window=3, rerender_capacity=4, capacity=256)
        b, f = 8, 4
        poses = jnp.stack([dolly_trajectory(
            f, start=(0.03 * i, -0.3, -2.0), target=(0.0, 0.0, 6.0))
            for i in range(b)])
        counts = jnp.asarray([4, 3, 4, 0, 2, 4, 1, 4], jnp.int32)
        phases = engine.stream_phases(b, cfg.window)
        carries = engine.init_stream_carries(cam, poses)
        # contiguous scene groups of B/D = 1..2 slots
        slot_scene = jnp.asarray([0, 0, 1, 1, 2, 2, 3, 3], jnp.int32)
        stack = reg.stack(ids, b)

        mesh = stream_mesh(b)
        assert mesh is not None and mesh.size == 8, mesh
        sharded = build_render_fn(cam, cfg, mesh, multi_scene=True)(
            stack, poses, counts, phases, carries, slot_scene)
        plain = build_render_fn(cam, cfg, None, multi_scene=True)(
            stack, poses, counts, phases, carries, slot_scene)
        err = float(jnp.max(jnp.abs(sharded.frames - plain.frames)))
        rec_ok = all(bool(np.array_equal(np.asarray(a), np.asarray(b)))
                     for a, b in zip(
                         jax.tree_util.tree_leaves(sharded.records.stacked),
                         jax.tree_util.tree_leaves(plain.records.stacked)))
        carry_ok = all(bool(np.allclose(np.asarray(a), np.asarray(b),
                                        atol=1e-5))
                       for a, b in zip(
                           jax.tree_util.tree_leaves(sharded.carries),
                           jax.tree_util.tree_leaves(plain.carries)))
        print(json.dumps({"err": err, "rec_ok": rec_ok,
                          "carry_ok": carry_ok}))
    """)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    r = json.loads(out.stdout.strip().splitlines()[-1])
    assert r["err"] < 1e-5
    assert r["rec_ok"] and r["carry_ok"]


def test_server_bucket_isolation_and_reuse(small_cam):
    """Scenes in different (N, K) buckets are served in separate slot
    GROUPS — each group single-bucket through its own executable, but a
    ragged round may dispatch both groups together (DESIGN.md §11) —
    while same-bucket scenes share one executable. The cache never
    compiles more than one executable per key."""
    reg = SceneRegistry((256, 512))
    same_a, same_b = [reg.register(s) for s in _scenes(2)]
    blob = reg.register(random_blob_scene(jax.random.PRNGKey(5), 90))
    cfg = RenderConfig(window=3, capacity=128)
    scfg = ServeConfig(slots=2, chunk=2, r_buckets=(8,),
                       scene_buckets=(256, 512))
    srv = StreamServer(reg, small_cam, cfg, scfg)
    for sid in (same_a.scene_id, same_b.scene_id, blob.scene_id):
        srv.attach(np.asarray(_poses(4)), scene_id=sid)
    report = srv.run(max_rounds=20)
    assert report["streams_finished"] == 3
    # one executable per scene bucket (B and R are single-bucket here)
    assert report["cache"]["distinct_executables"] == 2
    assert report["cache"]["hits"] >= 1     # same-bucket scenes reused one
    # every GROUP is single-bucket (the stackability invariant) ...
    for r in report["rounds_trace"]:
        for g in r.get("groups", []):
            buckets = {reg.bucket_of(i) for i in g["scene_ids"]}
            assert buckets <= {tuple(g["scene_bucket"])}
    # ... and with both buckets demanding from round one, the default
    # (mixed, uncapped) planner actually mixed them in one round.
    assert any(len(r.get("groups", [])) > 1
               for r in report["rounds_trace"])


def test_server_skew_starvation_bounded_wait(small_cam):
    """The starvation regression (the bug this PR fixes): 10:1 stream
    skew across two scene buckets with ``max_groups_per_round=1`` (the
    worst case — only one bucket can render per round). Aging must bound
    the minority bucket's wait by ``max_wait_rounds``, every stream must
    finish, and the mixed-round frames must match solo renders exactly
    (the scheduler moves WHEN a stream renders, never WHAT it renders).
    """
    reg = SceneRegistry((256, 512))
    major = reg.register(_scenes(1)[0])                        # (512, 4)
    minor = reg.register(random_blob_scene(jax.random.PRNGKey(7), 90))
    cfg = RenderConfig(window=3, capacity=128, rerender_capacity=8)
    scfg = ServeConfig(chunk=2, r_buckets=(8,), b_buckets=(2, 4),
                       scene_buckets=(256, 512), collect_frames=True,
                       admission=AdmissionConfig(max_wait_rounds=2,
                                                 max_groups_per_round=1))
    srv = StreamServer(reg, small_cam, cfg, scfg)

    total = 4
    majors = [srv.attach(np.asarray(_poses(total, dx=0.04 * i)),
                         scene_id=major.scene_id) for i in range(10)]
    minority = srv.attach(np.asarray(_poses(total, dx=-0.2)),
                          scene_id=minor.scene_id)
    report = srv.run(max_rounds=60)
    assert report["streams_finished"] == 11

    # the wait bound held for EVERY bucket, lifetime max
    assert report["fairness"]["max_wait_rounds"] <= 2
    minority_stats = report["per_bucket"][str(minor.bucket)]
    assert minority_stats["frames"] == total
    assert minority_stats["max_wait_rounds"] <= 2
    assert minority_stats["served_rounds"] >= 1
    assert 0.0 < minority_stats["share"] <= 1.0
    assert 0.0 < report["fairness"]["jain_service"] <= 1.0
    # one-bucket-per-round cap respected
    assert all(len(r.get("groups", [])) <= 1
               for r in report["rounds_trace"])
    # compile bound: <= policy.max_keys per bucket in use (2 B x 1 R x 2)
    assert report["cache"]["distinct_executables"] <= 4

    # scheduling changed WHEN, not WHAT: bit-parity vs solo renders
    for sess, entry in ((minority, minor), (majors[0], major)):
        got = np.concatenate(sess.frames)
        solo = engine.render_trajectory(
            entry.scene, small_cam,
            jnp.asarray(_poses(total, dx=-0.2 if sess is minority
                               else 0.0)),
            cfg, phase=sess.phase)
        np.testing.assert_allclose(got, np.asarray(solo.frames),
                                   atol=1e-5)
