"""Serving subsystem (repro.serve): masked/ragged batching equivalence,
session lifecycle, continuous batcher bookkeeping, bucketed executable
cache, device placement, and the serve loop smoke."""
import json
import os
import subprocess
import sys
import textwrap
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.core.pipeline import RenderConfig
from repro.scenes.trajectory import dolly_trajectory
from repro.serve import (ContinuousBatcher, ExecutableCache, PoissonTraffic,
                         ServeConfig, SessionManager, StreamServer,
                         TrafficConfig, build_render_fn, snap_capacity,
                         stream_mesh, suggest_capacity)

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_RECORD_FIELDS = ("is_full", "n_gaussians", "candidate_pairs", "raw_pairs",
                  "sort_pairs", "raster_pairs", "active",
                  "tiles_interpolated", "overflow_pairs", "overflow_tiles",
                  "block_of_tile", "order_in_block", "block_load")


def _poses(n, dx=0.0):
    return dolly_trajectory(n, start=(dx, -0.3, -2.0),
                            target=(0.0, 0.0, 6.0))


def _assert_records_equal(got, ref, sl=slice(None), msg=""):
    for name in _RECORD_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(got, name))[sl],
            np.asarray(getattr(ref, name))[sl], err_msg=f"{msg}:{name}")


# --- masked / ragged batching equivalence (the batcher's contract) --------

def test_masked_slots_match_solo(small_scene, small_cam):
    """A B-slot batch with idle slots and ragged counts: every active
    stream matches its solo ``render_trajectory`` to 1e-5 on frames and
    bit-exact on records, across phase offsets; padded frames read as
    zero frames / blanked records."""
    cfg = RenderConfig(window=3)
    b, f = 4, 5
    counts = (5, 0, 3, 0)
    phases = (0, 1, 2, 0)
    poses_b = jnp.stack([_poses(f, dx=0.04 * i) for i in range(b)])
    res = engine.render_streams(small_scene, small_cam, poses_b, cfg,
                                phases=phases, counts=counts)
    assert np.asarray(res.frame_active).tolist() == \
        [[k < c for k in range(f)] for c in counts]
    for i, c in enumerate(counts):
        if c == 0:
            assert not np.asarray(res.records.active)[i].any()
            np.testing.assert_array_equal(np.asarray(res.frames[i]), 0.0)
            continue
        solo = engine.render_trajectory(small_scene, small_cam, poses_b[i],
                                        cfg, phase=phases[i])
        # active prefix: bit-exact records, 1e-5 frames (scan prefix
        # property: frames 0..c-1 only depend on poses 0..c-1)
        np.testing.assert_allclose(np.asarray(res.frames[i][:c]),
                                   np.asarray(solo.frames[:c]), atol=1e-5)
        _assert_records_equal(res.records[i], solo.records.stacked,
                              sl=slice(0, c), msg=f"slot{i}")
        # masked tail: zero frames, no recorded work
        np.testing.assert_array_equal(np.asarray(res.frames[i][c:]), 0.0)
        assert not np.asarray(res.records.active)[i, c:].any()
        assert not np.asarray(res.records.is_full)[i, c:].any()


def test_chunked_resume_matches_one_shot(small_scene, small_cam):
    """Carry threading: a trajectory served in fixed-size chunks (ragged
    final chunk) is bit-identical in records and 1e-5 in frames to the
    one-shot scan — the key-frame schedule survives the chunk seams."""
    cfg = RenderConfig(window=3)
    b, chunk, total = 2, 4, 9
    phases = (1, 2)
    full = jnp.stack([_poses(total, dx=0.05 * i) for i in range(b)])
    ref = [engine.render_trajectory(small_scene, small_cam, full[i], cfg,
                                    phase=phases[i]) for i in range(b)]

    carries = engine.init_stream_carries(small_cam, full)
    got_frames = [[] for _ in range(b)]
    got_recs = [[] for _ in range(b)]
    for start in range(0, total, chunk):
        n = min(chunk, total - start)
        sl = full[:, start:start + n]
        pad = jnp.concatenate(
            [sl, jnp.repeat(sl[:, -1:], chunk - n, axis=1)], axis=1) \
            if n < chunk else sl
        res = engine.render_streams(small_scene, small_cam, pad, cfg,
                                    phases=phases,
                                    counts=(n,) * b, carries=carries)
        carries = res.carries
        for i in range(b):
            got_frames[i].append(np.asarray(res.frames[i][:n]))
            got_recs[i].append(
                jax.tree_util.tree_map(lambda a, i=i: np.asarray(a)[i, :n],
                                       res.records.stacked))
    for i in range(b):
        frames = np.concatenate(got_frames[i])
        np.testing.assert_allclose(frames, np.asarray(ref[i].frames),
                                   atol=1e-5)
        recs = jax.tree_util.tree_map(
            lambda *xs: np.concatenate(xs), *got_recs[i])
        _assert_records_equal(recs, ref[i].records.stacked,
                              msg=f"stream{i}")


# --- sessions -------------------------------------------------------------

def test_phase_assignment_least_loaded():
    m = SessionManager(window=4)
    sessions = [m.attach(closed=False) for _ in range(6)]
    assert [s.phase for s in sessions] == [0, 1, 2, 3, 0, 1]
    m.detach(sessions[2].sid)       # frees phase 2
    assert m.attach(closed=False).phase == 2
    assert len(m) == 6


def test_session_queue_and_done():
    m = SessionManager(window=3)
    s = m.attach(np.stack([np.eye(4, dtype=np.float32)] * 4), now=1.0)
    assert len(s.pending) == 4 and s.closed and not s.done
    s.pending.clear()
    assert s.done
    live = m.attach(closed=False)
    live.submit(np.eye(4, dtype=np.float32)[None], now=2.0)
    assert not live.done  # open stream never auto-detaches
    with pytest.raises(ValueError):
        m.attach(closed=True)  # closed + empty would never detach
    assert m._phase_load == [1, 1, 0]  # the failed attach freed its phase


# --- batcher --------------------------------------------------------------

def test_batcher_admit_build_commit(small_cam):
    m = SessionManager(window=4)
    bat = ContinuousBatcher(slots=2, chunk=3, cam=small_cam)
    eye = np.eye(4, dtype=np.float32)
    s0 = m.attach(np.stack([eye] * 2), now=0.0)   # drains in round 1
    s1 = m.attach(np.stack([eye] * 4), now=0.0)
    s2 = m.attach(np.stack([eye] * 1), now=0.0)   # waits for a slot
    assert bat.admit(m) == 2 and bat.bound == 2
    batch = bat.build(m)
    assert batch.sids == (s0.sid, s1.sid)
    assert np.asarray(batch.counts).tolist() == [2, 3]
    assert batch.active_frames == 5
    assert s2.slot is None

    # commit with a fake result: carries echo back, all sessions advance
    fake = SimpleNamespace(carries=batch.carries)
    detached = bat.commit(batch, fake, m, now=1.5)
    assert [s.sid for s in detached] == [s0.sid]
    assert s0.frames_rendered == 2 and list(s0.latencies) == [1.5, 1.5]
    assert s1.frames_rendered == 3 and len(s1.pending) == 1
    assert bat.admit(m) == 1      # s2 takes the freed slot
    assert bat.build(m).sids == (s2.sid, s1.sid)


def test_batcher_external_detach_frees_slot(small_cam):
    """A stream cancelled via manager.detach mid-flight must not leak
    its slot."""
    m = SessionManager(window=4)
    bat = ContinuousBatcher(slots=1, chunk=2, cam=small_cam)
    eye = np.eye(4, dtype=np.float32)
    s0 = m.attach(np.stack([eye] * 4), now=0.0)
    bat.admit(m)
    batch = bat.build(m)
    m.detach(s0.sid)              # cancelled while the chunk renders
    assert bat.commit(batch, SimpleNamespace(carries=batch.carries),
                      m, now=1.0) == []
    assert bat.bound == 0         # the slot is free again
    s1 = m.attach(np.stack([eye] * 2), now=1.0)
    assert bat.admit(m) == 1 and bat.build(m).sids == (s1.sid,)

    # detach BETWEEN rounds (before build): build() itself frees the slot
    m.detach(s1.sid)
    assert bat.build(m).sids == (None,)
    assert bat.bound == 0
    s2 = m.attach(np.stack([eye] * 2), now=2.0)
    assert bat.admit(m) == 1 and bat.build(m).sids == (s2.sid,)


# --- bucketed cache + capacity selection ----------------------------------

def test_snap_capacity():
    assert snap_capacity(3, (8, 16, 32)) == 8
    assert snap_capacity(8, (8, 16, 32)) == 8
    assert snap_capacity(9, (8, 16, 32)) == 16
    assert snap_capacity(999, (8, 16, 32)) == 32


def test_suggest_capacity_from_records():
    # 6 sparse frames wanting 10 tiles (2 active + 8 overflow), 1 full
    # frame (ignored), 1 padding frame (masked out via frame_mask).
    t = 16
    active = np.zeros((8, t), bool)
    active[:, :2] = True
    overflow = np.full((8,), 8)
    is_full = np.zeros((8,), bool)
    is_full[0] = True
    active[7] = False
    overflow[7] = 0           # padding frame: would drag the quantile down
    mask = np.ones((8,), bool)
    mask[7] = False
    recs = SimpleNamespace(active=active, overflow_tiles=overflow,
                           is_full=is_full)
    assert suggest_capacity(recs, 0.9, (4, 16, 32), frame_mask=mask) == 16
    assert suggest_capacity(recs, 0.9, (4, 16, 32)) == 16  # quantile robust
    # no sparse frames observed -> smallest bucket
    empty = SimpleNamespace(active=active[:1], overflow_tiles=overflow[:1],
                            is_full=is_full[:1])
    assert suggest_capacity(empty, 0.9, (4, 16, 32)) == 4


def test_executable_cache_counts():
    cache = ExecutableCache()
    built = []
    fn_a = cache.get(("b8", "r16"), lambda: built.append("a") or (lambda: "a"))
    assert cache.get(("b8", "r16"), lambda: built.append("!") or None) is fn_a
    cache.get(("b8", "r32"), lambda: built.append("b") or (lambda: "b"))
    assert built == ["a", "b"]
    assert cache.stats()["distinct_executables"] == 2
    assert cache.hits == 1 and cache.misses == 2
    with pytest.raises(KeyError):
        cache.get(("never", "built"))


# --- placement ------------------------------------------------------------

def test_stream_mesh_single_device_degrades(small_scene, small_cam):
    assert stream_mesh(8) is None          # test process sees ONE device
    # mesh=None falls back to the plain engine path: same executable as
    # render_streams (shares shapes/cfg with test_masked_slots_match_solo
    # so this hits a warm jit cache).
    cfg = RenderConfig(window=3)
    b, f = 4, 5
    poses = jnp.stack([_poses(f, dx=0.04 * i) for i in range(b)])
    counts = jnp.asarray([5, 0, 3, 0], jnp.int32)
    phases = jnp.asarray([0, 1, 2, 0], jnp.int32)
    carries = engine.init_stream_carries(small_cam, poses)
    fn = build_render_fn(small_cam, cfg, None)
    got = fn(small_scene, poses, counts, phases, carries)
    ref = engine.render_streams(small_scene, small_cam, poses, cfg,
                                phases=phases, counts=counts)
    np.testing.assert_allclose(np.asarray(got.frames),
                               np.asarray(ref.frames), atol=1e-6)


@pytest.mark.slow
def test_sharded_streams_match_single_device():
    """8 slots over 8 host devices (local B=1 -> real lax.cond per
    device): frames within 1e-5 and records bit-exact vs the plain
    single-logical-batch path."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(_REPO, "src"), JAX_PLATFORMS="cpu")
    script = textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import engine
        from repro.core.camera import make_camera, look_at
        from repro.core.pipeline import RenderConfig
        from repro.scenes.synthetic import structured_scene
        from repro.scenes.trajectory import dolly_trajectory
        from repro.serve import build_render_fn, stream_mesh

        scene = structured_scene(jax.random.PRNGKey(7), 300, clutter=0.5)
        cam = make_camera(look_at((0.0, -0.3, -2.0), (0.0, 0.0, 6.0)),
                          width=48, height=48)
        cfg = RenderConfig(window=3, rerender_capacity=4, capacity=256)
        b, f = 8, 4
        poses = jnp.stack([dolly_trajectory(
            f, start=(0.03 * i, -0.3, -2.0), target=(0.0, 0.0, 6.0))
            for i in range(b)])
        counts = jnp.asarray([4, 3, 4, 0, 2, 4, 1, 4], jnp.int32)
        phases = engine.stream_phases(b, cfg.window)
        carries = engine.init_stream_carries(cam, poses)

        mesh = stream_mesh(b)
        assert mesh is not None and mesh.size == 8, mesh
        sharded = build_render_fn(cam, cfg, mesh)(
            scene, poses, counts, phases, carries)
        plain = engine.render_streams(scene, cam, poses, cfg,
                                      phases=phases, counts=counts)
        err = float(jnp.max(jnp.abs(sharded.frames - plain.frames)))
        rec_ok = all(bool(np.array_equal(np.asarray(a), np.asarray(b)))
                     for a, b in zip(
                         jax.tree_util.tree_leaves(sharded.records.stacked),
                         jax.tree_util.tree_leaves(plain.records.stacked)))
        carry_ok = all(bool(np.allclose(np.asarray(a), np.asarray(b),
                                        atol=1e-5))
                       for a, b in zip(
                           jax.tree_util.tree_leaves(sharded.carries),
                           jax.tree_util.tree_leaves(plain.carries)))
        print(json.dumps({"err": err, "rec_ok": rec_ok,
                          "carry_ok": carry_ok}))
    """)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    r = json.loads(out.stdout.strip().splitlines()[-1])
    assert r["err"] < 1e-5
    assert r["rec_ok"] and r["carry_ok"]


# --- serve loop smoke (the CI tier-1 smoke: 4 streams, 2 buckets) ---------

def test_serve_smoke(small_scene, small_cam):
    cfg = RenderConfig(window=4, capacity=256)
    scfg = ServeConfig(slots=4, chunk=3, r_buckets=(4, 8), quantile=0.9,
                       adapt_every=2)
    srv = StreamServer(small_scene, small_cam, cfg, scfg)
    traffic = PoissonTraffic(TrafficConfig(n_streams=4, rate=2.0,
                                           min_frames=4, max_frames=7,
                                           seed=1))
    rep = srv.run(traffic, max_rounds=40)
    assert rep["streams_served"] == 4
    assert rep["streams_finished"] == 4     # everything drained + detached
    assert rep["frames"] >= 16
    assert 0.0 < rep["slot_utilization"] <= 1.0
    assert rep["latency_p50_ms"] is not None
    assert rep["latency_p99_ms"] >= rep["latency_p50_ms"]
    # bucketed executables: at most one compile per R bucket
    assert rep["cache"]["distinct_executables"] <= len(scfg.r_buckets)
    assert rep["cache"]["misses"] == rep["cache"]["distinct_executables"]
    assert rep["capacity"] in scfg.r_buckets
    assert not srv.manager.sessions      # no leaked sessions or slots
    assert srv.batcher.bound == 0
