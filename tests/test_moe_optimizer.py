"""MoE dispatch invariants + optimizer properties (hypothesis)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.models import layers as L
from repro.train.optimizer import (OptimizerConfig, adamw_update,
                                   init_opt_state, lr_schedule)


def _moe_cfg(e=8, k=2, shared=0):
    base = get_config("moonshot-v1-16b-a3b").reduced()
    return dataclasses.replace(base, num_experts=e, experts_per_token=k,
                               num_shared_experts=shared)


def test_moe_identity_when_experts_equal():
    """If every expert has identical weights, routing cannot matter:
    output == the dense MLP with those weights (dropless regime)."""
    cfg = _moe_cfg(e=4, k=2)
    key = jax.random.PRNGKey(0)
    p = L.init_moe(key, cfg, jnp.float32)
    # overwrite experts with copies of expert 0
    for name in ("w_in", "w_gate", "w_out"):
        p[name] = jnp.broadcast_to(p[name][0:1], p[name].shape)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.3
    y, aux = L.moe_block(p, x, cfg)
    dense = {"w_in": p["w_in"][0], "w_gate": p["w_gate"][0],
             "w_out": p["w_out"][0]}
    ref = L.mlp(dense, x, "swiglu")
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4)


def test_moe_gate_weights_normalized():
    """Scaling all router logits shifts gates but outputs stay bounded and
    finite; aux loss is ~1 at uniform routing."""
    cfg = _moe_cfg(e=8, k=2)
    p = L.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    p["router"] = jnp.zeros_like(p["router"])  # uniform routing
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, cfg.d_model)) * 0.3
    y, aux = L.moe_block(p, x, cfg)
    assert bool(jnp.isfinite(y).all())
    # Switch aux loss (k-normalized) at perfectly uniform routing == 1.0
    assert 0.8 < float(aux) < 1.2


def test_moe_capacity_drops_surface_in_training_regime():
    """Above the dropless threshold, a hot expert must drop tokens (the
    LDU-cap analogue): output for dropped tokens falls back to shared/0."""
    cfg = dataclasses.replace(_moe_cfg(e=8, k=1),
                              moe_capacity_factor=1.0)
    p = L.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    # zero router -> uniform logits -> top-1 tie-break routes EVERY token
    # to expert 0 (deterministic hot expert)
    p["router"] = jnp.zeros_like(p["router"])
    t = 8192  # above the 4096 dropless threshold
    x = jax.random.normal(jax.random.PRNGKey(3), (1, t, cfg.d_model)) * 0.3
    y, aux = L.moe_block(p, x, cfg)
    capacity = int(round(t * 1 / 8 * 1.0))
    # tokens beyond capacity contribute ~zero routed output
    norms = jnp.linalg.norm(y[0], axis=-1)
    n_nonzero = int(jnp.sum(norms > 1e-6))
    assert n_nonzero <= capacity + 1, (n_nonzero, capacity)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 10 ** 6))
def test_lr_schedule_bounds(step):
    cfg = OptimizerConfig(peak_lr=3e-4, warmup_steps=100, total_steps=10000)
    lr = float(lr_schedule(cfg, jnp.int32(min(step, cfg.total_steps))))
    assert 0.0 <= lr <= cfg.peak_lr * (1 + 1e-6)


def test_adamw_moves_toward_gradient():
    params = {"w": jnp.ones((4, 4))}
    opt = init_opt_state(params)
    grads = {"w": jnp.ones((4, 4))}
    cfg = OptimizerConfig(peak_lr=1e-2, warmup_steps=1, total_steps=10,
                          weight_decay=0.0)
    new_p, new_opt, m = adamw_update(grads, opt, params, cfg)
    assert float(jnp.max(new_p["w"])) < 1.0  # moved against +grad
    assert int(new_opt.step) == 1
    assert float(m["grad_norm"]) == pytest.approx(4.0)


def test_adamw_clips_grad_norm():
    params = {"w": jnp.zeros((8,))}
    opt = init_opt_state(params)
    g_small = {"w": jnp.full((8,), 1e-3)}
    g_huge = {"w": jnp.full((8,), 1e3)}
    cfg = OptimizerConfig(peak_lr=1e-2, warmup_steps=1, total_steps=10,
                          clip_norm=1.0, weight_decay=0.0)
    p1, *_ = adamw_update(g_small, opt, params, cfg)
    p2, *_ = adamw_update(g_huge, opt, params, cfg)
    # after clipping, the huge-grad step is no bigger than ~the small one
    assert float(jnp.max(jnp.abs(p2["w"]))) <= \
        float(jnp.max(jnp.abs(p1["w"]))) * 1.5 + 1e-8
