"""GPipe-over-pod pipeline: schedule correctness on an 8-device host mesh."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.distributed.pipeline import bubble_fraction

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_bubble_fraction():
    assert bubble_fraction(1, 4) == 0.0
    assert abs(bubble_fraction(2, 8) - 1 / 9) < 1e-9
    assert bubble_fraction(4, 4) == pytest.approx(3 / 7)


@pytest.mark.slow
def test_pipeline_matches_sequential():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(_REPO, "src"), JAX_PLATFORMS="cpu")
    script = textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp
        from repro.distributed.pipeline import pipeline_apply

        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        l, b, s, d = 6, 8, 4, 16
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        w = jax.random.normal(ks[0], (l, d, d)) * 0.3
        bvec = jax.random.normal(ks[1], (l, d)) * 0.1
        x = jax.random.normal(ks[2], (b, s, d))

        def layer(lp, h):
            wi, bi = lp
            return jax.nn.tanh(h @ wi + bi)

        # sequential reference
        ref = x
        for i in range(l):
            ref = layer((w[i], bvec[i]), ref)

        out = pipeline_apply(layer, (w, bvec), x, mesh=mesh, num_micro=4)
        err = float(jnp.max(jnp.abs(out - ref)))
        print(json.dumps({"err": err}))
    """)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["err"] < 1e-5, out
