"""Fused plan-slot kernel (kernels/raster_plan.py) parity and contract.

Interpret-mode sweeps of ``impl="pallas_fused"`` against ``jnp_chunked``
and the sequential ``ref`` oracle (DESIGN.md §9: on matching inputs the
three paths must agree to float tolerance; the fused path must ALSO
agree when its per-slot lanes arrive depth-shuffled, because the GSU
sort runs in-kernel). Small cases ride the fast tier; the
RenderConfig-default K=512 case and the engine-scan sweep are ``slow``.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import binning, intersect, plan as plan_mod, projection
from repro.core.engine import render_trajectory
from repro.core.pipeline import (RenderConfig, render_full_frame,
                                 render_sparse_frame)
from repro.kernels import ops
from repro.scenes.trajectory import dolly_trajectory

ATOL = 2e-5


def _tile_inputs(scene, cam, capacity):
    proj = projection.preprocess(scene, cam)
    grid = intersect.make_tile_grid(cam)
    mask = intersect.tait_mask(proj, grid)
    bins = binning.build_tile_bins(mask, proj.depth, capacity)
    tg = binning.gather_tiles(proj, bins)
    return (tg.mean2d, tg.conic, tg.rgb, tg.opacity, tg.depth,
            grid.origins, bins.count)


def _shuffle_lanes(args, seed=0):
    """Permute each slot's first `count` lanes (attrs move together) —
    the kernel's input contract: packed, any depth order. Returns the
    shuffled args plus the per-slot permutations (lane_contrib follows
    INPUT lane order, so it permutes with the lanes)."""
    mean2d, conic, rgb, opacity, depth, origins, counts = args
    rng = np.random.default_rng(seed)
    outs = [np.asarray(a).copy() for a in (mean2d, conic, rgb, opacity,
                                           depth)]
    perms = []
    for r, c in enumerate(np.asarray(counts)):
        p = rng.permutation(int(c))
        perms.append(p)
        for o in outs:
            o[r, :int(c)] = o[r, :int(c)][p]
    return tuple(jnp.asarray(o) for o in outs) + (origins, counts), perms


@pytest.mark.parametrize("capacity,chunk", [
    (64, 16),
    (96, 32),     # non-pow2 K exercises the kernel's internal padding
    (128, 64),
    pytest.param(512, 64, marks=pytest.mark.slow),  # RenderConfig default
])
def test_fused_matches_jnp_and_ref(small_scene, small_cam, capacity, chunk):
    args = _tile_inputs(small_scene, small_cam, capacity)
    o_ref = ops.raster_tiles(*args, impl="ref")
    o_jnp = ops.raster_tiles(*args, impl="jnp_chunked", chunk=chunk)
    o_fused = ops.raster_tiles(*args, impl="pallas_fused", chunk=chunk)
    for got, want, tol in [(o_fused[0], o_jnp[0], 0.0),
                           (o_fused[1], o_jnp[1], 0.0),
                           (o_fused[2], o_jnp[2], 0.0),
                           (o_fused[3], o_jnp[3], 0.0)]:
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=tol)
    np.testing.assert_array_equal(np.asarray(o_fused[4]),
                                  np.asarray(o_jnp[4]))
    np.testing.assert_allclose(np.asarray(o_fused[0]), np.asarray(o_ref[0]),
                               atol=ATOL)
    np.testing.assert_allclose(np.asarray(o_fused[1]), np.asarray(o_ref[1]),
                               atol=ATOL)


def test_fused_sorts_in_kernel(small_scene, small_cam):
    """Depth-shuffled lanes must render identically: the GSU sort is
    part of the kernel, not a caller obligation. lane_contrib is the one
    output that rightly differs — it reports per-INPUT-lane mass, so it
    follows the applied permutation exactly."""
    args = _tile_inputs(small_scene, small_cam, 64)
    shuf_args, perms = _shuffle_lanes(args)
    o_sorted = ops.raster_tiles(*args, impl="pallas_fused", chunk=32)
    o_shuf = ops.raster_tiles(*shuf_args, impl="pallas_fused", chunk=32)
    for a, b in zip(o_shuf[:5], o_sorted[:5]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    contrib = np.asarray(o_sorted[5])
    contrib_shuf = np.asarray(o_shuf[5])
    counts = np.asarray(args[6])
    for r, p in enumerate(perms):
        c = int(counts[r])
        np.testing.assert_array_equal(contrib_shuf[r, :c],
                                      contrib[r, :c][p])
        np.testing.assert_array_equal(contrib_shuf[r, c:], 0.0)


def test_masked_slots_render_empty(small_scene, small_cam):
    """slot_active=False slots (counts zeroed, the plan contract) read
    as empty: rgb 0, T=1, 0 processed pairs; active slots unchanged."""
    m, c, r, o, d, org, counts = _tile_inputs(small_scene, small_cam, 64)
    active = jnp.arange(counts.shape[0]) % 2 == 0
    counts_m = jnp.where(active, counts, 0)
    out = ops.raster_tiles(m, c, r, o, d, org, counts_m,
                           impl="pallas_fused", chunk=32,
                           slot_active=active)
    ref = ops.raster_tiles(m, c, r, o, d, org, counts,
                           impl="pallas_fused", chunk=32)
    na = ~np.asarray(active)
    assert np.all(np.asarray(out[0])[na] == 0.0)
    assert np.all(np.asarray(out[1])[na] == 1.0)
    assert np.all(np.asarray(out[4])[na] == 0)
    a = np.asarray(active)
    np.testing.assert_array_equal(np.asarray(out[0])[a],
                                  np.asarray(ref[0])[a])
    np.testing.assert_array_equal(np.asarray(out[4])[a],
                                  np.asarray(ref[4])[a])


def test_empty_input_renders_background(small_cam):
    t, k = small_cam.num_tiles, 64
    z = jnp.zeros
    out = ops.raster_tiles(z((t, k, 2)), jnp.ones((t, k, 3)), z((t, k, 3)),
                           z((t, k)), z((t, k)), z((t, 2)),
                           z((t,), jnp.int32), impl="pallas_fused", chunk=32)
    assert np.allclose(out[0], 0.0)
    assert np.allclose(out[1], 1.0)
    assert int(np.asarray(out[4]).sum()) == 0


def test_fused_rejects_non_pow2_chunk(small_scene, small_cam):
    args = _tile_inputs(small_scene, small_cam, 64)
    with pytest.raises(ValueError, match="power of two"):
        ops.raster_tiles(*args, impl="pallas_fused", chunk=48)


# ---- full pipeline parity (plans, masked slots, overflow) ---------------

def _cfg(impl, **kw):
    base = dict(capacity=128, window=3, chunk=32)
    base.update(kw)
    return RenderConfig(impl=impl, **base)


def test_full_frame_parity(small_scene, small_cam):
    """All-tiles plan (R = T) through the fused path: bit-consistent
    frames and identical records vs jnp_chunked."""
    outs = {}
    for impl in ("jnp_chunked", "pallas_fused"):
        fn = jax.jit(functools.partial(render_full_frame, cfg=_cfg(impl)))
        outs[impl] = fn(small_scene, small_cam)
    a, b = outs["jnp_chunked"], outs["pallas_fused"]
    np.testing.assert_allclose(np.asarray(b[0].rgb), np.asarray(a[0].rgb),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(b[0].transmittance),
                               np.asarray(a[0].transmittance), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(b[2].raster_pairs),
                                  np.asarray(a[2].raster_pairs))


@pytest.mark.parametrize("rcap", [None, 8, 2])
def test_sparse_frame_parity(small_scene, small_cam, rcap):
    """Sparse plans across R — uncapped, compacted, and overflowing
    (rcap=2 forces re-render tiles past R to degrade to interpolation,
    identically on both paths)."""
    poses = dolly_trajectory(2, start=(0.0, -0.3, -2.0),
                             target=(0.0, 0.0, 6.0))
    outs = {}
    for impl in ("jnp_chunked", "pallas_fused"):
        cfg = _cfg(impl, rerender_capacity=rcap)
        full_fn = jax.jit(functools.partial(render_full_frame, cfg=cfg))
        _, state, _ = full_fn(small_scene, small_cam.with_pose(poses[0]))
        sparse_fn = jax.jit(functools.partial(render_sparse_frame, cfg=cfg))
        outs[impl] = sparse_fn(small_scene, small_cam.with_pose(poses[0]),
                               small_cam.with_pose(poses[1]), state)
    a, b = outs["jnp_chunked"], outs["pallas_fused"]
    np.testing.assert_allclose(np.asarray(b[0]), np.asarray(a[0]),
                               atol=1e-5)
    np.testing.assert_array_equal(np.asarray(b[2].raster_pairs),
                                  np.asarray(a[2].raster_pairs))
    assert int(b[2].overflow_tiles) == int(a[2].overflow_tiles)
    if rcap == 2:
        assert int(b[2].overflow_tiles) > 0  # the case actually overflows


def test_engine_scan_parity(small_scene, small_cam):
    """The scanned engine's full/sparse lax.cond both hit the fused path
    via RenderConfig.impl — whole-trajectory frames bit-consistent."""
    poses = dolly_trajectory(3, start=(0.0, -0.3, -2.0),
                             target=(0.0, 0.0, 6.0))
    res = {}
    for impl in ("jnp_chunked", "pallas_fused"):
        cfg = _cfg(impl, capacity=64, rerender_capacity=8, window=2)
        res[impl] = render_trajectory(small_scene, small_cam, poses, cfg)
    np.testing.assert_allclose(np.asarray(res["pallas_fused"].frames),
                               np.asarray(res["jnp_chunked"].frames),
                               atol=1e-5)
    np.testing.assert_array_equal(
        np.asarray(res["pallas_fused"].records.raster_pairs),
        np.asarray(res["jnp_chunked"].records.raster_pairs))


@pytest.mark.slow
def test_engine_scan_parity_large(small_scene, wide_cam):
    """Wider frame, default-capacity bins, longer trajectory."""
    poses = dolly_trajectory(5, start=(0.5, -0.5, -3.0),
                             target=(0.0, 0.0, 6.0))
    res = {}
    for impl in ("jnp_chunked", "pallas_fused"):
        cfg = RenderConfig(impl=impl, window=3, rerender_capacity=16)
        res[impl] = render_trajectory(small_scene, wide_cam, poses, cfg)
    np.testing.assert_allclose(np.asarray(res["pallas_fused"].frames),
                               np.asarray(res["jnp_chunked"].frames),
                               atol=1e-5)


def test_default_impl_tracks_backend():
    """pallas_fused is the default on TPU backends, jnp_chunked elsewhere
    — and RenderConfig() picks it up via its default factory."""
    expected = "pallas_fused" if jax.default_backend() == "tpu" \
        else "jnp_chunked"
    assert ops.default_impl() == expected
    assert RenderConfig().impl == expected
    # Explicit impl always wins over the backend default.
    assert dataclasses.replace(RenderConfig(), impl="ref").impl == "ref"
