"""Flash (online-softmax) attention vs materialized softmax oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import _sdpa, flash_attention


def _rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32) * 0.5


@pytest.mark.parametrize("b,s,g,hq,d,causal", [
    (2, 128, 2, 2, 32, True),
    (2, 128, 2, 2, 32, False),
    (1, 256, 1, 4, 64, True),
    (2, 64, 4, 1, 16, True),
])
def test_flash_matches_sdpa(b, s, g, hq, d, causal):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (b, s, g, hq, d))
    k = _rand(ks[1], (b, g, s, d))
    v = _rand(ks[2], (b, g, s, d))
    mask = None
    if causal:
        m = jnp.arange(s)[None, :] <= jnp.arange(s)[:, None]
        mask = m[None, None, None]
    ref = _sdpa(q, k, v, mask)
    out = flash_attention(q, k, v, causal=causal, scale=1.0 / d ** 0.5,
                          q_chunk=32, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)


def test_flash_causal_skip_equivalent():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    b, s, g, hq, d = 1, 256, 2, 2, 32
    q = _rand(ks[0], (b, s, g, hq, d))
    k = _rand(ks[1], (b, g, s, d))
    v = _rand(ks[2], (b, g, s, d))
    full = flash_attention(q, k, v, causal=True, scale=0.2,
                           q_chunk=64, kv_chunk=64)
    skip = flash_attention(q, k, v, causal=True, scale=0.2,
                           q_chunk=64, kv_chunk=64, causal_skip=True)
    np.testing.assert_allclose(np.asarray(skip), np.asarray(full),
                               atol=2e-5, rtol=1e-4)


def test_flash_rect_prefill_chunks():
    """Odd chunking (non-divisible) falls back to a single block."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    b, s, g, hq, d = 1, 96, 1, 2, 16
    q = _rand(ks[0], (b, s, g, hq, d))
    k = _rand(ks[1], (b, g, s, d))
    v = _rand(ks[2], (b, g, s, d))
    m = jnp.arange(s)[None, :] <= jnp.arange(s)[:, None]
    ref = _sdpa(q, k, v, m[None, None, None])
    out = flash_attention(q, k, v, causal=True, scale=1.0 / 4.0,
                          q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=1e-4)
