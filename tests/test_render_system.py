"""End-to-end behaviour: the tiled pipeline equals the brute-force oracle,
and capacity overflow is surfaced, never silent."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import binning, intersect, projection, raster
from repro.core.metrics import psnr, ssim


@pytest.mark.parametrize("method", ["aabb", "tait", "exact"])
def test_tiled_render_matches_oracle(small_scene, small_cam, method):
    """Any superset-of-exact test must reproduce the oracle image: pairs a
    test adds beyond 'exact' contribute alpha < 1/255 by construction."""
    proj = projection.preprocess(small_scene, small_cam)
    grid = intersect.make_tile_grid(small_cam)
    mask = intersect.intersect(proj, grid, method)
    bins = binning.build_tile_bins(mask, proj.depth, 256)
    assert int(bins.overflow.sum()) == 0, "test needs capacity headroom"
    out = raster.render_from_bins(proj, bins, grid)
    oracle = raster.render_oracle(proj, small_cam)
    np.testing.assert_allclose(out.rgb, oracle.rgb, atol=3e-5)
    np.testing.assert_allclose(out.transmittance, oracle.transmittance,
                               atol=3e-5)


def test_pallas_impl_end_to_end(small_scene, small_cam):
    proj = projection.preprocess(small_scene, small_cam)
    grid = intersect.make_tile_grid(small_cam)
    mask = intersect.tait_mask(proj, grid)
    bins = binning.build_tile_bins(mask, proj.depth, 128)
    out_p = raster.render_from_bins(proj, bins, grid, impl="pallas")
    out_j = raster.render_from_bins(proj, bins, grid, impl="jnp_chunked")
    np.testing.assert_allclose(out_p.rgb, out_j.rgb, atol=2e-5)


def test_overflow_is_counted_not_silent(small_scene, small_cam):
    proj = projection.preprocess(small_scene, small_cam)
    grid = intersect.make_tile_grid(small_cam)
    mask = intersect.tait_mask(proj, grid)
    full_bins = binning.build_tile_bins(mask, proj.depth, 512)
    max_count = int(full_bins.count.max())
    tiny = binning.build_tile_bins(mask, proj.depth, 32)
    if max_count > 32:
        assert int(tiny.overflow.sum()) > 0
        assert int(tiny.overflow.sum()) == int(full_bins.count.sum()) - int(
            tiny.count.sum())


def test_untile_roundtrip(small_cam):
    key = jax.random.PRNGKey(0)
    img = jax.random.uniform(key, (small_cam.height, small_cam.width, 3))
    tiles = raster.tile_view(img, small_cam.tiles_x, small_cam.tiles_y)
    back = raster.untile(tiles, small_cam.tiles_x, small_cam.tiles_y)
    np.testing.assert_array_equal(np.asarray(img), np.asarray(back))


def test_metrics_sanity():
    key = jax.random.PRNGKey(0)
    img = jax.random.uniform(key, (64, 64, 3))
    assert float(psnr(img, img)) > 100
    assert float(ssim(img, img)) > 0.999
    noisy = jnp.clip(img + 0.1 * jax.random.normal(key, img.shape), 0, 1)
    assert float(psnr(img, noisy)) < 30
    assert float(ssim(img, noisy)) < 0.99
