"""Contribution-culling invariants (core/culling.py, DESIGN.md §12).

Pinned contracts:
  - ``cull_threshold=0.0`` (and the record_contrib instrumentation) is
    bit-exact with the pre-culling pipeline on full AND sparse frames,
    on both the jnp_chunked and pallas_fused raster impls;
  - the contribution statistics agree bit-for-bit across impls;
  - padding / masked bin lanes report exactly zero contribution, and the
    per-Gaussian prior is inf exactly on never-considered Gaussians;
  - ``cull_pairs`` keeps inf-prior Gaussians, respects the warp gate,
    demotes fully-culled slots, and counts what it removed;
  - a nonzero threshold reduces sort/raster work and re-render demand on
    a real trajectory while staying visually faithful (>= 30 dB PSNR vs
    the uncull render on sparse frames).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import culling
from repro.core.engine import render_trajectory
from repro.core.metrics import psnr
from repro.core.pipeline import (RenderConfig, render_full_frame,
                                 render_sparse_frame)
from repro.core.plan import rerender_demand
from repro.scenes.trajectory import dolly_trajectory

_BASE_FIELDS = ("is_full", "n_gaussians", "candidate_pairs", "raw_pairs",
                "sort_pairs", "raster_pairs", "active",
                "tiles_interpolated", "overflow_pairs", "overflow_tiles",
                "block_of_tile", "order_in_block", "block_load")


def _cfg(**kw):
    kw.setdefault("impl", "jnp_chunked")
    return RenderConfig(capacity=64, window=3, chunk=32, **kw)


def _frame_pair(scene, cam, cfg):
    """One full frame + one sparse frame warped from it."""
    poses = dolly_trajectory(2, start=(0.0, -0.3, -2.0),
                             target=(0.0, 0.0, 6.0))
    ref_cam = cam.with_pose(poses[0])
    tgt_cam = cam.with_pose(poses[1])
    out, state, rec_full = render_full_frame(scene, ref_cam, cfg)
    rgb, _, rec_sparse = render_sparse_frame(scene, ref_cam, tgt_cam,
                                             state, cfg)
    return out.rgb, rec_full, rgb, rec_sparse


@pytest.mark.parametrize("impl", ["jnp_chunked", "pallas_fused"])
def test_threshold_zero_bit_exact(small_scene, small_cam, impl):
    """Threading the contribution machinery (record_contrib=True,
    threshold 0) must not move a single bit of the render or the
    pre-existing record fields, full and sparse alike."""
    base = _frame_pair(small_scene, small_cam, _cfg(impl=impl))
    inst = _frame_pair(small_scene, small_cam,
                       _cfg(impl=impl, record_contrib=True))
    np.testing.assert_array_equal(np.asarray(base[0]), np.asarray(inst[0]))
    np.testing.assert_array_equal(np.asarray(base[2]), np.asarray(inst[2]))
    for base_rec, inst_rec in ((base[1], inst[1]), (base[3], inst[3])):
        assert base_rec.lane_contrib is None
        assert inst_rec.lane_contrib is not None
        for name in _BASE_FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(base_rec, name)),
                np.asarray(getattr(inst_rec, name)), err_msg=name)
        assert int(base_rec.culled_pairs) == 0
        assert int(inst_rec.culled_pairs) == 0


def test_contrib_identical_across_impls(small_scene, small_cam):
    """jnp_chunked and pallas_fused share the blend math exactly, so the
    recorded contributions (and the derived prior) match bit-for-bit."""
    cfg_j = _cfg(impl="jnp_chunked", record_contrib=True)
    cfg_f = _cfg(impl="pallas_fused", record_contrib=True)
    _, st_j, rec_j = render_full_frame(small_scene, small_cam, cfg_j)
    _, st_f, rec_f = render_full_frame(small_scene, small_cam, cfg_f)
    np.testing.assert_array_equal(np.asarray(rec_j.lane_contrib),
                                  np.asarray(rec_f.lane_contrib))
    np.testing.assert_array_equal(np.asarray(st_j.contrib),
                                  np.asarray(st_f.contrib))


def test_pad_lanes_and_unseen_gaussians(small_scene, small_cam):
    """Lanes past a tile's bin count carry exactly 0 contribution; the
    prior is finite non-negative exactly where the Gaussian was binned
    somewhere and inf (keep-all) everywhere else."""
    cfg = _cfg(record_contrib=True)
    _, state, rec = render_full_frame(small_scene, small_cam, cfg)
    contrib = np.asarray(rec.lane_contrib)
    counts = np.asarray(rec.sort_pairs)
    assert contrib.shape[0] == counts.shape[0]
    for t in range(contrib.shape[0]):
        assert np.all(contrib[t, counts[t]:] == 0.0), t
    assert np.all(contrib >= 0.0)
    prior = np.asarray(state.contrib)
    finite = np.isfinite(prior)
    assert finite.any() and (~finite).any()
    assert np.all(prior[finite] >= 0.0)
    assert np.all(np.isinf(prior[~finite]))


def test_cull_pairs_unit():
    """Keep rules, the warp gate, slot demotion, and the removed count
    on a hand-built mask."""
    mask = jnp.asarray([[1, 1, 1],
                        [1, 1, 1],
                        [0, 0, 1],
                        [1, 0, 0]], bool)          # (N=4, R=3)
    slot_active = jnp.asarray([True, True, True])
    tile_ids = jnp.asarray([0, 1, 2], jnp.int32)
    prior = jnp.asarray([jnp.inf, 0.0, 1.0, 0.2])
    gate = jnp.asarray([True, True, False])        # slot 2 ungated
    new_mask, new_active, culled = culling.cull_pairs(
        mask, slot_active, tile_ids, prior, gate, 0.5)
    want = np.asarray([[1, 1, 1],       # inf prior: always kept
                       [0, 0, 1],       # 0.0 < 0.5: culled where gated
                       [0, 0, 1],       # only present in ungated slot 2
                       [0, 0, 0]], bool)  # 0.2 < 0.5: culled
    np.testing.assert_array_equal(np.asarray(new_mask), want)
    assert int(culled) == 3
    # No slot lost ALL its pairs here; now isolate g3 in its own slot.
    mask2 = jnp.asarray([[0, 0, 0],
                         [0, 0, 0],
                         [0, 0, 0],
                         [0, 1, 0]], bool)
    m2, active2, culled2 = culling.cull_pairs(
        mask2, slot_active, tile_ids, prior, gate, 0.5)
    assert not np.any(np.asarray(m2))
    np.testing.assert_array_equal(np.asarray(active2),
                                  [True, False, True])
    assert int(culled2) == 1
    # Empty-before slots are NOT demoted (nothing was culled from them).
    m3, active3, _ = culling.cull_pairs(
        jnp.zeros((4, 3), bool), slot_active, tile_ids, prior, gate, 0.5)
    np.testing.assert_array_equal(np.asarray(active3), [True, True, True])


def test_cull_trajectory_reduces_work_keeps_quality(small_scene, small_cam):
    """The end-to-end claim on a streamed trajectory: a nonzero
    threshold culls pairs on sparse frames (never key frames), shrinks
    sort work and re-render demand, and the frames stay >= 30 dB PSNR
    against the uncull render."""
    poses = dolly_trajectory(6, start=(0.0, -0.3, -2.0),
                             target=(0.0, 0.0, 6.0))
    base_cfg = _cfg()
    cull_cfg = dataclasses.replace(base_cfg, cull_threshold=0.05)
    base = render_trajectory(small_scene, small_cam, poses, base_cfg)
    cull = render_trajectory(small_scene, small_cam, poses, cull_cfg)

    is_full = np.asarray(base.records.is_full)
    culled = np.asarray(cull.records.culled_pairs)
    assert np.all(culled[is_full] == 0)
    assert culled[~is_full].sum() > 0

    sort_base = np.asarray(base.records.sort_pairs).sum(axis=-1)
    sort_cull = np.asarray(cull.records.sort_pairs).sum(axis=-1)
    assert np.all(sort_cull <= sort_base)
    assert sort_cull[~is_full].sum() < sort_base[~is_full].sum()

    demand_base = np.asarray(rerender_demand(
        base.records.active, base.records.overflow_tiles))
    demand_cull = np.asarray(rerender_demand(
        cull.records.active, cull.records.overflow_tiles))
    assert np.all(demand_cull <= demand_base)

    # Key frames are bit-identical (culling never touches them) and
    # sparse frames stay visually faithful.
    for f in range(poses.shape[0]):
        if is_full[f]:
            np.testing.assert_array_equal(np.asarray(cull.frames[f]),
                                          np.asarray(base.frames[f]))
        else:
            assert float(psnr(cull.frames[f], base.frames[f])) >= 30.0
