"""LDU scheduling invariants (paper Sec. V-B) + hypothesis properties,
plus parity of the device-side (jnp) LDU port against the numpy golden
reference across all four policies."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.load_balance import (Schedule, ldu_schedule, load_stats,
                                     morton_order, morton_rank, schedule)
from repro.core.streaming import (AcceleratorConfig, FrameWork,
                                  simulate_sequence, throughput)

POLICIES = ("static_blocked", "round_robin", "dynamic", "ls_gaussian")


def test_morton_is_permutation():
    for tx, ty in [(4, 4), (8, 8), (8, 6), (16, 16)]:
        order = morton_order(tx, ty)
        assert sorted(order.tolist()) == list(range(tx * ty))


def test_morton_locality():
    """Z-order neighbors are spatially close: mean manhattan distance of
    consecutive tiles must beat row-major's long row jumps at same size."""
    tx = ty = 16
    order = morton_order(tx, ty)
    xy = np.stack([order % tx, order // tx], 1)
    d_morton = np.abs(np.diff(xy, axis=0)).sum(1).mean()
    assert d_morton < 2.0  # row-major scan has mean ~1.94 w/ 15-jumps; Z ~1.3


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 5000), min_size=16, max_size=256),
       st.integers(2, 32))
def test_cap_property(workloads, b):
    """No block (except possibly the forced last) exceeds (1+1/N)W + one
    tile: the paper's deferral rule."""
    w = np.array(workloads, np.int64)
    t = len(w)
    tx = ty = int(np.ceil(np.sqrt(t)))
    w_full = np.zeros(tx * ty, np.int64)
    w_full[:t] = w
    sched = schedule(w_full, b, policy="ls_gaussian", tiles_x=tx, tiles_y=ty)
    w_ideal = max(w_full.sum() / b, 1.0)
    n_avg = max((tx * ty) / b, 1.0)
    cap = (1 + 1 / n_avg) * w_ideal
    loads = load_stats(sched, w_full)["block_loads"]
    for j in range(b - 1):  # last block takes the remainder by design
        ids = np.where(sched.block_of_tile == j)[0]
        if len(ids) <= 1:
            continue
        assert loads[j] <= cap + w_full[ids].max(), (j, loads[j], cap)


def test_all_tiles_scheduled_once():
    rng = np.random.default_rng(0)
    w = rng.integers(0, 1000, size=64)
    s = schedule(w, 8, policy="ls_gaussian", tiles_x=8, tiles_y=8)
    assert np.all(s.block_of_tile >= 0)
    seen = set()
    for j in range(8):
        for tid in s.tiles_of_block(j):
            assert tid not in seen
            seen.add(tid)
    assert len(seen) == 64


def test_light_to_heavy_order():
    rng = np.random.default_rng(1)
    w = rng.integers(0, 1000, size=64)
    s = schedule(w, 4, policy="ls_gaussian", tiles_x=8, tiles_y=8)
    for j in range(4):
        tiles = s.tiles_of_block(j)
        loads = w[tiles]
        assert np.all(np.diff(loads) >= 0), "intra-block must be ascending"


def test_inactive_tiles_skipped():
    w = np.ones(64, np.int64)
    active = np.zeros(64, bool)
    active[[3, 17, 42]] = True
    s = schedule(w, 4, policy="ls_gaussian", tiles_x=8, tiles_y=8,
                 active=active)
    assert set(np.where(s.block_of_tile >= 0)[0]) == {3, 17, 42}


def test_morton_rank_inverts_morton_order():
    """Device morton_rank is the inverse permutation of numpy morton_order."""
    for tx, ty in [(4, 4), (8, 8), (8, 6), (16, 16)]:
        order = morton_order(tx, ty)
        rank = np.asarray(morton_rank(tx, ty))
        np.testing.assert_array_equal(np.argsort(rank, kind="stable"), order)


@pytest.mark.parametrize("policy", POLICIES)
def test_device_schedule_matches_numpy(policy):
    """The jitted jnp LDU port produces bit-identical block assignments
    and intra-block orders to numpy ``schedule`` — random workloads,
    random active subsets, varying grid shapes and block counts."""
    for seed in range(12):
        rng = np.random.default_rng(seed)
        tx = int(rng.choice([4, 8, 16]))
        ty = int(rng.choice([4, 6, 8]))
        t = tx * ty
        w = rng.integers(0, 5000, size=t)
        active = rng.random(t) < rng.choice([0.0, 0.4, 1.0])
        b = int(rng.integers(2, 33))
        ref = schedule(w, b, policy=policy, tiles_x=tx, tiles_y=ty,
                       active=active)
        dev_fn = jax.jit(lambda wl, a: ldu_schedule(
            wl, b, policy=policy, tiles_x=tx, tiles_y=ty, active=a))
        blk, order = dev_fn(jnp.asarray(w), jnp.asarray(active))
        err = f"{policy} seed={seed} ({tx}x{ty}, b={b})"
        np.testing.assert_array_equal(np.asarray(blk), ref.block_of_tile,
                                      err_msg=err)
        np.testing.assert_array_equal(np.asarray(order), ref.order_in_block,
                                      err_msg=err)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 5000), min_size=16, max_size=64),
       st.integers(2, 16))
def test_device_schedule_parity_property(workloads, b):
    """Property form of the parity check on the paper's policy."""
    w = np.zeros(64, np.int64)
    w[:len(workloads)] = workloads
    ref = schedule(w, b, policy="ls_gaussian", tiles_x=8, tiles_y=8)
    blk, order = ldu_schedule(jnp.asarray(w), b, policy="ls_gaussian",
                              tiles_x=8, tiles_y=8)
    np.testing.assert_array_equal(np.asarray(blk), ref.block_of_tile)
    np.testing.assert_array_equal(np.asarray(order), ref.order_in_block)


def _imbalanced_frame(rng, t=256, heavy_frac=0.08):
    """Order-of-magnitude tile-load spread, like the paper's Fig. 5.
    Raster-dominated (pairs >> gaussians); heavy tiles stay below a whole
    block's ideal budget, as DPES-culled real scenes do."""
    w = rng.integers(20, 80, size=t).astype(np.int64)
    heavy = rng.choice(t, int(t * heavy_frac), replace=False)
    w[heavy] = rng.integers(300, 700, size=len(heavy))
    return FrameWork(
        n_gaussians=2000, candidate_pairs=int(w.sum() * 1.2),
        raw_pairs=w * 2, sort_pairs=w, raster_pairs=w,
        active=np.ones(t, bool), n_warp_pixels=0, tiles_x=16, tiles_y=16)


def test_ls_schedule_beats_baseline_utilization():
    """Core claim of Tab. I: balanced distribution lifts utilization."""
    rng = np.random.default_rng(7)
    frames = [_imbalanced_frame(rng) for _ in range(6)]
    cfg = AcceleratorConfig(num_blocks=32)
    base = throughput(simulate_sequence(
        frames, cfg, policy="round_robin", workload_source="raw",
        light_to_heavy=False, streaming=False), cfg.num_blocks)
    ls = throughput(simulate_sequence(
        frames, cfg, policy="ls_gaussian", workload_source="dpes",
        light_to_heavy=True, streaming=True), cfg.num_blocks)
    assert ls["utilization"] > base["utilization"] + 0.1
    assert ls["cycles_per_frame"] < base["cycles_per_frame"]


def test_light_to_heavy_reduces_sort_stall():
    rng = np.random.default_rng(3)
    frames = [_imbalanced_frame(rng) for _ in range(6)]
    # sorter much slower: stalls become visible
    cfg = AcceleratorConfig(num_blocks=32, gsu_rate=2.0)
    with_ld2 = throughput(simulate_sequence(
        frames, cfg, policy="ls_gaussian", light_to_heavy=True),
        cfg.num_blocks)
    without = throughput(simulate_sequence(
        frames, cfg, policy="ls_gaussian", light_to_heavy=False),
        cfg.num_blocks)
    assert with_ld2["sort_stall"] <= without["sort_stall"] + 1e-6
