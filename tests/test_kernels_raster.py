"""Per-kernel allclose sweeps: Pallas rasterizer vs the sequential oracle.

Sweeps tile-capacity K, chunk size, dtype and degenerate inputs, as
required for every Pallas kernel (interpret=True executes the kernel body
on CPU).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import binning, intersect, projection
from repro.kernels import ops

ATOL = 2e-5


def _tile_inputs(scene, cam, capacity):
    proj = projection.preprocess(scene, cam)
    grid = intersect.make_tile_grid(cam)
    mask = intersect.tait_mask(proj, grid)
    bins = binning.build_tile_bins(mask, proj.depth, capacity)
    tg = binning.gather_tiles(proj, bins)
    return (tg.mean2d, tg.conic, tg.rgb, tg.opacity, tg.depth,
            grid.origins, bins.count)


@pytest.mark.parametrize("capacity,chunk", [(64, 16), (128, 32), (128, 64),
                                            (256, 64), (256, 128)])
def test_pallas_matches_ref_shapes(small_scene, small_cam, capacity, chunk):
    args = _tile_inputs(small_scene, small_cam, capacity)
    o_ref = ops.raster_tiles(*args, impl="ref")
    o_pal = ops.raster_tiles(*args, impl="pallas", chunk=chunk)
    np.testing.assert_allclose(o_pal[0], o_ref[0], atol=ATOL)  # rgb
    np.testing.assert_allclose(o_pal[1], o_ref[1], atol=ATOL)  # trans
    np.testing.assert_allclose(o_pal[2], o_ref[2], atol=1e-4)  # exp depth
    np.testing.assert_allclose(o_pal[3], o_ref[3], atol=ATOL)  # trunc depth


@pytest.mark.parametrize("chunk", [16, 32, 64])
def test_jnp_chunked_matches_ref(small_scene, wide_cam, chunk):
    args = _tile_inputs(small_scene, wide_cam, 128)
    o_ref = ops.raster_tiles(*args, impl="ref")
    o_jnp = ops.raster_tiles(*args, impl="jnp_chunked", chunk=chunk)
    for a, b, tol in [(o_jnp[0], o_ref[0], ATOL), (o_jnp[1], o_ref[1], ATOL),
                      (o_jnp[2], o_ref[2], 1e-4), (o_jnp[3], o_ref[3], ATOL)]:
        np.testing.assert_allclose(a, b, atol=tol)


def test_processed_pairs_consistent(small_scene, small_cam):
    """Chunk-granular processed counts bracket the exact oracle count."""
    args = _tile_inputs(small_scene, small_cam, 128)
    chunk = 32
    p_ref = ops.raster_tiles(*args, impl="ref")[4]
    p_pal = ops.raster_tiles(*args, impl="pallas", chunk=chunk)[4]
    p_jnp = ops.raster_tiles(*args, impl="jnp_chunked", chunk=chunk)[4]
    np.testing.assert_array_equal(np.asarray(p_pal), np.asarray(p_jnp))
    assert np.all(np.asarray(p_pal) >= np.asarray(p_ref))
    assert np.all(np.asarray(p_pal) <= np.asarray(p_ref) + chunk)


def test_empty_tiles_render_background(small_cam):
    """Zero-opacity input: transmittance 1 everywhere, rgb 0."""
    t = small_cam.num_tiles
    k = 64
    z = jnp.zeros
    out = ops.raster_tiles(z((t, k, 2)), jnp.ones((t, k, 3)), z((t, k, 3)),
                           z((t, k)), z((t, k)),
                           z((t, 2)), z((t,), jnp.int32), impl="pallas",
                           chunk=32)
    assert np.allclose(out[0], 0.0)
    assert np.allclose(out[1], 1.0)
    assert int(np.asarray(out[4]).sum()) == 0


def test_opaque_front_gaussian_early_stops(small_cam):
    """A huge opaque splat in front: T ~ 0 and later gaussians skipped."""
    t, k, chunk = small_cam.num_tiles, 128, 32
    mean = jnp.tile(jnp.array([32.0, 32.0]), (t, k, 1))
    conic = jnp.tile(jnp.array([1e-6, 0.0, 1e-6]), (t, k, 1))  # ~flat alpha
    rgb = jnp.ones((t, k, 3)) * 0.5
    opac = jnp.ones((t, k)) * 0.995
    depth = jnp.tile(jnp.arange(k, dtype=jnp.float32)[None] + 1.0, (t, 1))
    origins = jnp.zeros((t, 2))
    counts = jnp.full((t,), k, jnp.int32)
    out = ops.raster_tiles(mean, conic, rgb, opac, depth, origins, counts,
                           impl="pallas", chunk=chunk)
    # T freezes at the last blended value (sticky done): 0.005^1 here.
    assert float(np.max(out[1])) < 0.01
    # alpha=0.995 -> T after j splats = 0.005^j < 1e-4 at j=2; so only the
    # first chunk is ever touched.
    assert int(np.max(np.asarray(out[4]))) <= chunk
    o_ref = ops.raster_tiles(mean, conic, rgb, opac, depth, origins, counts,
                             impl="ref")
    np.testing.assert_allclose(out[0], o_ref[0], atol=ATOL)


def test_bfloat16_inputs_upcast(small_scene, small_cam):
    """Kernel casts to f32 internally: bf16 inputs agree loosely."""
    args = _tile_inputs(small_scene, small_cam, 128)
    bf = [a.astype(jnp.bfloat16).astype(jnp.float32) if a.dtype == jnp.float32
          else a for a in args]
    o32 = ops.raster_tiles(*args, impl="pallas", chunk=32)
    obf = ops.raster_tiles(*bf, impl="pallas", chunk=32)
    assert float(jnp.mean(jnp.abs(o32[0] - obf[0]))) < 0.05
