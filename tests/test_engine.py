"""Scanned streaming engine (core/engine.py): golden equivalence against
the legacy Python-loop driver, and batched multi-stream rendering."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import (render_streams, render_trajectory,
                               stream_phases)
from repro.core.pipeline import RenderConfig, render_trajectory_py
from repro.scenes.trajectory import dolly_trajectory

N_FRAMES = 7

_COUNT_FIELDS = ("n_gaussians", "candidate_pairs", "raw_pairs",
                 "sort_pairs", "raster_pairs", "tiles_interpolated",
                 "overflow_pairs", "overflow_tiles",
                 "block_of_tile", "order_in_block", "block_load")


def _poses(n=N_FRAMES, dx=0.0):
    return dolly_trajectory(n, start=(dx, -0.3, -2.0),
                            target=(0.0, 0.0, 6.0))


@pytest.mark.parametrize("window,rcap", [(1, None), (3, None), (5, None),
                                         (3, 2)])
def test_scan_matches_python_loop(small_scene, small_cam, window, rcap):
    """One-executable scan == per-frame dispatch loop: frames within 1e-5,
    per-frame workload records exactly equal."""
    cfg = RenderConfig(window=window, rerender_capacity=rcap)
    poses = _poses()
    ref = render_trajectory_py(small_scene, small_cam, poses, cfg)
    got = render_trajectory(small_scene, small_cam, poses, cfg)
    np.testing.assert_allclose(np.asarray(got.frames),
                               np.asarray(ref.frames), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(got.records.is_full),
                                  np.asarray(ref.records.is_full))
    np.testing.assert_array_equal(np.asarray(got.records.active),
                                  np.asarray(ref.records.active))
    for name in _COUNT_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(got.records, name)),
            np.asarray(getattr(ref.records, name)), err_msg=name)


def test_full_frame_schedule(small_scene, small_cam):
    """Frame f is full iff (f + phase) % window == 0, frame 0 always."""
    cfg = RenderConfig(window=3)
    res = render_trajectory(small_scene, small_cam, _poses(), cfg, phase=2)
    expect = [f == 0 or (f + 2) % 3 == 0 for f in range(N_FRAMES)]
    assert np.asarray(res.records.is_full).tolist() == expect


def test_stacked_records_indexing(small_scene, small_cam):
    """StackedRecords: attribute access is stacked, indexing is per-frame,
    and both views agree."""
    res = render_trajectory(small_scene, small_cam, _poses(),
                            RenderConfig(window=3))
    recs = res.records
    assert len(recs) == N_FRAMES
    t = small_cam.num_tiles
    assert recs.raster_pairs.shape == (N_FRAMES, t)
    assert recs[1].raster_pairs.shape == (t,)
    np.testing.assert_array_equal(np.asarray(recs[1].raster_pairs),
                                  np.asarray(recs.raster_pairs)[1])
    assert sum(int(r.is_full) for r in recs) == \
        int(np.asarray(recs.is_full).sum())


def test_keep_states_stacked(small_scene, small_cam):
    res = render_trajectory(small_scene, small_cam, _poses(),
                            RenderConfig(window=3), keep_states=True)
    h, w = small_cam.height, small_cam.width
    assert res.states is not None
    assert res.states.rgb.shape == (N_FRAMES, h, w, 3)
    assert res.states.source_mask.shape == (N_FRAMES, h, w)
    # the carried state's rgb is the composed frame
    np.testing.assert_allclose(np.asarray(res.states.rgb[1]),
                               np.asarray(res.frames[1]), atol=1e-6)


def test_frame_idx_survives_midtrajectory_keyframes(small_scene, small_cam):
    """state.frame_idx is the TRUE global index: mid-trajectory key frames
    (frames 3 and 6 at window=3) must not reset the counter — and the
    scanned engine must agree with the legacy loop on it."""
    cfg = RenderConfig(window=3)
    res = render_trajectory(small_scene, small_cam, _poses(), cfg,
                            keep_states=True)
    np.testing.assert_array_equal(np.asarray(res.states.frame_idx),
                                  np.arange(N_FRAMES))
    ref = render_trajectory_py(small_scene, small_cam, _poses(), cfg,
                               keep_states=True)
    np.testing.assert_array_equal(np.asarray(res.states.frame_idx),
                                  np.asarray(ref.states.frame_idx))


def test_streams_match_solo(small_scene, small_cam):
    """B=3 staggered vmapped streams each reproduce their solo render."""
    cfg = RenderConfig(window=4)
    b, f = 3, 6
    poses_b = jnp.stack([_poses(f, dx=0.03 * i) for i in range(b)])
    res = render_streams(small_scene, small_cam, poses_b, cfg)
    assert res.frames.shape == (b, f, small_cam.height, small_cam.width, 3)
    for i in range(b):
        solo = render_trajectory(small_scene, small_cam, poses_b[i], cfg,
                                 phase=int(res.phases[i]))
        np.testing.assert_allclose(np.asarray(res.frames[i]),
                                   np.asarray(solo.frames), atol=1e-5)
        np.testing.assert_array_equal(
            np.asarray(res.records.raster_pairs)[i],
            np.asarray(solo.records.raster_pairs))
        np.testing.assert_array_equal(
            np.asarray(res.records.is_full)[i],
            np.asarray(solo.records.is_full))


def test_stream_phase_staggering(small_scene, small_cam):
    """Past warmup, staggered streams never all re-key on the same step."""
    cfg = RenderConfig(window=4)
    b, f = 3, 6  # same shapes/cfg as test_streams_match_solo: shares the jit cache
    poses_b = jnp.stack([_poses(f, dx=0.03 * i) for i in range(b)])
    res = render_streams(small_scene, small_cam, poses_b, cfg)
    is_full = np.asarray(res.records.is_full)          # (B, F)
    assert bool(is_full[:, 0].all()), "frame 0 must be full on every stream"
    per_step = is_full[:, 1:].sum(axis=0)
    assert int(per_step.max()) <= int(np.ceil(b / cfg.window)), \
        f"key-frame spike: {per_step.tolist()}"


def test_stream_phases_cover_window():
    phases = np.asarray(stream_phases(4, 4))
    assert sorted(phases.tolist()) == [0, 1, 2, 3]
    phases = np.asarray(stream_phases(3, 5))
    assert len(set(phases.tolist())) == 3
    assert all(0 <= p < 5 for p in phases.tolist())
