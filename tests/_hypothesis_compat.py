"""Fallback shims so the suite collects with or without ``hypothesis``.

When hypothesis is installed, this module re-exports the real
``given`` / ``settings`` / ``st``. Otherwise it provides deterministic
example-based stand-ins: each ``@given`` test body runs over a fixed
number of samples drawn from seeded mini-strategies, so the property
tests still exercise a spread of inputs (reproducibly) instead of
erroring at collection time.

Usage in test files (instead of ``from hypothesis import ...``):

    from _hypothesis_compat import given, settings, st
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import random

    _MAX_EXAMPLES = 5  # deterministic budget per test when shimmed
    _SEED = 0xC0FFEE


    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)


    class _StrategiesShim:
        """The subset of ``hypothesis.strategies`` the suite uses."""

        @staticmethod
        def integers(min_value=0, max_value=2 ** 31 - 1):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.getrandbits(1)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements))

        @staticmethod
        def lists(elements, min_size=0, max_size=10, **_kw):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elements.draw(rng) for _ in range(n)]

            return _Strategy(draw)


    st = _StrategiesShim()


    def given(*strategies, **kw_strategies):
        """Run the test over ``_MAX_EXAMPLES`` seeded deterministic draws.

        The wrapper takes NO parameters (like hypothesis' own wrapper),
        so pytest does not mistake the strategy arguments for fixtures.
        """

        def deco(fn):
            def run():
                rng = random.Random(_SEED)
                for _ in range(_MAX_EXAMPLES):
                    args = tuple(s.draw(rng) for s in strategies)
                    kwargs = {k: s.draw(rng)
                              for k, s in kw_strategies.items()}
                    fn(*args, **kwargs)

            run.__name__ = getattr(fn, "__name__", "given_shim")
            run.__doc__ = fn.__doc__
            return run

        return deco


    def settings(*_a, **_kw):
        """No-op stand-in for ``hypothesis.settings``."""

        def deco(fn):
            return fn

        return deco
