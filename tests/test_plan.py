"""TilePlan-driven rendering (DESIGN.md §2): the compacted sparse path is
equivalent to the dense path, compiles to (R, K)-shaped stages, and the
device-LDU schedule recorded inside the jitted scan matches the numpy
golden ``load_balance.schedule``."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import binning, intersect, plan as plan_mod, projection, raster
from repro.core.load_balance import schedule
from repro.core.pipeline import (RenderConfig, render_full_frame,
                                 render_sparse_frame, render_trajectory)
from repro.core.streaming import (AcceleratorConfig, frameworks_from_stacked,
                                  simulate_sequence)
from repro.scenes.trajectory import dolly_trajectory

_PER_TILE_FIELDS = ("raw_pairs", "sort_pairs", "raster_pairs", "active",
                    "block_of_tile", "order_in_block")


def _poses(n=4):
    return dolly_trajectory(n, start=(0.0, -0.3, -2.0),
                            target=(0.0, 0.0, 6.0))


def _sparse_inputs(scene, cam, cfg):
    poses = _poses(2)
    full = jax.jit(render_full_frame, static_argnames="cfg")
    _, state, _ = full(scene, cam.with_pose(poses[0]), cfg=cfg)
    return cam.with_pose(poses[0]), cam.with_pose(poses[1]), state


def test_plan_basic_structure(small_cam):
    tx, ty = small_cam.tiles_x, small_cam.tiles_y
    t = tx * ty
    p = plan_mod.full_plan(tx, ty)
    assert p.num_slots == t
    assert sorted(np.asarray(p.tile_ids).tolist()) == list(range(t))
    assert bool(np.asarray(p.slot_active).all())

    rerender = jnp.zeros((t,), bool).at[jnp.array([1, 5, 9])].set(True)
    sp = plan_mod.sparse_plan(rerender, tx, ty, 2)
    assert sp.num_slots == 2
    assert int(np.asarray(sp.slot_active).sum()) == 2
    assert int(sp.overflow_tiles) == 1
    # selected slots really are re-render tiles
    assert all(bool(rerender[i]) for i in np.asarray(sp.tile_ids).tolist())


def test_compacted_sparse_matches_dense(small_scene, small_cam):
    """Plan equivalence: with enough slots for every re-render tile, the
    (R, K) compacted path reproduces the dense (T, K) path — frames to
    1e-5, FrameRecord pair counts exactly."""
    dense_cfg = RenderConfig(window=10, rerender_capacity=None)
    ref_cam, tgt_cam, state = _sparse_inputs(small_scene, small_cam,
                                             dense_cfg)
    sparse = jax.jit(render_sparse_frame, static_argnames="cfg")
    rgb_d, _, rec_d = sparse(small_scene, ref_cam, tgt_cam, state,
                             cfg=dense_cfg)
    n_rr = int(np.asarray(rec_d.active).sum())
    assert 0 < n_rr < small_cam.num_tiles, "test needs a partial re-render"

    cap_cfg = RenderConfig(window=10, rerender_capacity=n_rr)
    rgb_c, _, rec_c = sparse(small_scene, ref_cam, tgt_cam, state,
                             cfg=cap_cfg)
    assert int(rec_c.overflow_tiles) == 0
    np.testing.assert_allclose(np.asarray(rgb_c), np.asarray(rgb_d),
                               atol=1e-5)
    np.testing.assert_array_equal(np.asarray(rec_c.candidate_pairs),
                                  np.asarray(rec_d.candidate_pairs))
    np.testing.assert_array_equal(np.asarray(rec_c.overflow_pairs),
                                  np.asarray(rec_d.overflow_pairs))
    for name in _PER_TILE_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(rec_c, name)),
                                      np.asarray(getattr(rec_d, name)),
                                      err_msg=name)


def test_full_frame_matches_dense_reference(small_scene, small_cam):
    """The all-tiles plan (Morton-permuted slots + scatter back) is a pure
    reordering: it must equal the dense render_from_bins reference."""
    cfg = RenderConfig()
    out, _, rec = jax.jit(render_full_frame, static_argnames="cfg")(
        small_scene, small_cam, cfg=cfg)
    proj = projection.preprocess(small_scene, small_cam, near=cfg.near)
    grid = intersect.make_tile_grid(small_cam)
    mask = intersect.tait_mask(proj, grid)
    bins = binning.build_tile_bins(mask, proj.depth, cfg.capacity)
    ref = raster.render_from_bins(proj, bins, grid)
    np.testing.assert_allclose(np.asarray(out.rgb), np.asarray(ref.rgb),
                               atol=1e-6)
    np.testing.assert_array_equal(np.asarray(out.processed_pairs),
                                  np.asarray(ref.processed_pairs))
    np.testing.assert_array_equal(np.asarray(rec.sort_pairs),
                                  np.asarray(bins.count))


def _collect_shapes(jaxpr, acc):
    """All output-var shapes in a jaxpr, recursing into sub-jaxprs."""
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            if aval is not None and hasattr(aval, "shape"):
                acc.add(tuple(aval.shape))
        for p in eqn.params.values():
            for sub in (p if isinstance(p, (tuple, list)) else (p,)):
                inner = getattr(sub, "jaxpr", sub)
                if hasattr(inner, "eqns"):
                    _collect_shapes(inner, acc)


def test_sparse_stages_are_plan_shaped(small_scene, small_cam):
    """The compacted sparse frame compiles with (N, R)/(R, K) intersect
    and binning intermediates and NO dense (N, T)/(T, K) ones — the
    wrappers really collapse onto the shared plan pipeline."""
    rcap, kcap = 4, 128
    cfg = RenderConfig(window=10, rerender_capacity=rcap, capacity=kcap)
    ref_cam, tgt_cam, state = _sparse_inputs(small_scene, small_cam, cfg)
    n = small_scene.means.shape[0]
    t = small_cam.num_tiles
    assert rcap < t

    jx = jax.make_jaxpr(
        functools.partial(render_sparse_frame, cfg=cfg))(
        small_scene, ref_cam, tgt_cam, state)
    shapes = set()
    _collect_shapes(jx.jaxpr, shapes)
    assert (n, rcap) in shapes, "compacted (N, R) intersect mask missing"
    assert (rcap, kcap) in shapes, "compacted (R, K) bins missing"
    assert (n, t) not in shapes, "dense (N, T) intersect mask still built"
    assert (t, kcap) not in shapes, "dense (T, K) bins still built"

    # ...while the full frame plans all T tiles (R = T).
    jx_full = jax.make_jaxpr(
        functools.partial(render_full_frame, cfg=cfg))(small_scene, tgt_cam)
    full_shapes = set()
    _collect_shapes(jx_full.jaxpr, full_shapes)
    assert (n, t) in full_shapes
    assert (t, kcap) in full_shapes


def test_recorded_schedule_matches_numpy_golden(small_scene, small_cam):
    """The device LDU runs inside the jitted scan (no host callback) and
    its recorded block assignments match numpy ``schedule()`` on the
    identical workloads/active sets, frame by frame."""
    cfg = RenderConfig(window=2, ldu_blocks=8)
    res = render_trajectory(small_scene, small_cam, _poses(4), cfg)
    for f in range(4):
        rec = res.records[f]
        wl = np.asarray(rec.sort_pairs)
        active = np.asarray(rec.active)
        ref = schedule(wl, cfg.ldu_blocks, policy="ls_gaussian",
                       tiles_x=small_cam.tiles_x, tiles_y=small_cam.tiles_y,
                       active=active)
        np.testing.assert_array_equal(np.asarray(rec.block_of_tile),
                                      ref.block_of_tile, err_msg=f"frame {f}")
        np.testing.assert_array_equal(np.asarray(rec.order_in_block),
                                      ref.order_in_block, err_msg=f"frame {f}")
        # per-block load summary is consistent with the assignment
        loads = np.asarray(rec.block_load)
        assert loads.shape == (cfg.ldu_blocks,)
        for b in range(cfg.ldu_blocks):
            assert loads[b] == wl[ref.block_of_tile == b].sum()


def test_simulator_consumes_recorded_schedule(small_scene, small_cam):
    """policy='recorded' serves the FrameRecord's device schedule and
    reproduces the host-side ls_gaussian simulation exactly."""
    cfg = RenderConfig(window=2, ldu_blocks=8)
    res = render_trajectory(small_scene, small_cam, _poses(4), cfg)
    frames = frameworks_from_stacked(
        res.records, small_cam.tiles_x, small_cam.tiles_y,
        small_cam.width * small_cam.height)
    assert frames[0].num_blocks == cfg.ldu_blocks
    acfg = AcceleratorConfig(num_blocks=cfg.ldu_blocks)
    rec_t = simulate_sequence(frames, acfg, policy="recorded")
    ls_t = simulate_sequence(frames, acfg, policy="ls_gaussian",
                             workload_source="dpes", light_to_heavy=True)
    for a, b in zip(rec_t, ls_t):
        assert a.frame_end == pytest.approx(b.frame_end)
        assert a.sort_stall == pytest.approx(b.sort_stall)
        assert a.utilization == pytest.approx(b.utilization)

    bad = AcceleratorConfig(num_blocks=cfg.ldu_blocks * 2)
    with pytest.raises(ValueError, match="recorded schedule"):
        simulate_sequence(frames, bad, policy="recorded")


def test_scatter_slots_masks_inactive(small_cam):
    tx, ty = small_cam.tiles_x, small_cam.tiles_y
    t = tx * ty
    rerender = jnp.zeros((t,), bool).at[jnp.array([2, 7])].set(True)
    sp = plan_mod.sparse_plan(rerender, tx, ty, 4)  # 2 padded slots
    vals = jnp.full((4,), 9, jnp.int32)
    out = np.asarray(plan_mod.scatter_slots(sp, vals, t, fill=-3))
    assert (out[np.asarray(rerender)] == 9).all()
    assert (out[~np.asarray(rerender)] == -3).all()


def test_rerender_demand_dtype_contract():
    """rerender_demand is always int32, whatever mixture of jnp/numpy
    int/float/bool dtypes the stacked records arrive in, and it counts
    overflow_tiles on top of the active set (the serve layer compares it
    to bucket sizes on host with np.asarray)."""
    active = np.zeros((3, 8), bool)
    active[0, :5] = True
    active[2, :8] = True
    overflow = np.asarray([0, 0, 7])
    d = plan_mod.rerender_demand(active, overflow)
    assert d.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(d), [5, 0, 15])
    # Host-side float records (e.g. loaded from a JSON artifact) must
    # not silently promote the result to float.
    d_f = plan_mod.rerender_demand(active.astype(np.float64),
                                   overflow.astype(np.float32))
    assert d_f.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(d_f), [5, 0, 15])
    # int64 overflow counters (default numpy int on host) stay int32.
    d_i = plan_mod.rerender_demand(active, overflow.astype(np.int64))
    assert d_i.dtype == jnp.int32
    # Stacked (B, F, T) records reduce over the last axis only.
    stacked = np.broadcast_to(active, (2, 3, 8))
    d_b = plan_mod.rerender_demand(stacked, np.broadcast_to(overflow,
                                                            (2, 3)))
    assert d_b.shape == (2, 3)
    np.testing.assert_array_equal(np.asarray(d_b),
                                  [[5, 0, 15], [5, 0, 15]])
