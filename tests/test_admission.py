"""Admission control (serve/admission.py) + the serve-loop bugfix
satellites: round planning (drain vs mixed, aging, group caps), SLO
classes, backpressure, Jain fairness, replay traffic traces, named
bucket validation errors, warmup accounting/memo hygiene, sim-trace
drop accounting, and executable eviction when a scene bucket leaves
use."""
import jax
import numpy as np
import pytest

from repro.core.pipeline import RenderConfig
from repro.scenes.synthetic import random_blob_scene, structured_scene
from repro.scenes.trajectory import dolly_trajectory
from repro.serve import (AdmissionConfig, AdmissionController,
                         AdmissionRejected, BucketDemand, ExecutableCache,
                         ReplayTraffic, SceneRegistry, ServeConfig, SLOClass,
                         StreamServer, TrafficConfig, burst_trace,
                         jain_index, skewed_trace)

A, B = (256, 1), (512, 4)   # two scene buckets, as (padded N, sh K)


def _poses(n, dx=0.0):
    return dolly_trajectory(n, start=(dx, -0.3, -2.0),
                            target=(0.0, 0.0, 6.0))


def _demand(**buckets):
    """BucketDemand map from kwargs-ish pairs: _demand(a=(pending, wait
    is managed by the controller), ...) — values are BucketDemand
    field dicts."""
    return {k: BucketDemand(**v) for k, v in buckets.items()}


# --- pure fairness math ---------------------------------------------------

def test_jain_index():
    assert jain_index([]) == 1.0
    assert jain_index([0.0, 0.0]) == 1.0        # nothing divided = fair
    assert jain_index([3.0, 3.0, 3.0]) == pytest.approx(1.0)
    assert jain_index([1.0, 0.0]) == pytest.approx(0.5)       # 1/n
    assert jain_index([4.0, 1.0]) == pytest.approx(25 / 34)


# --- config validation ----------------------------------------------------

def test_slo_and_config_validation():
    with pytest.raises(ValueError):
        SLOClass("bad", weight=0.0)
    with pytest.raises(ValueError):
        SLOClass("bad", max_wait_rounds=0)
    with pytest.raises(ValueError):
        AdmissionConfig(mode="fifo")
    with pytest.raises(ValueError):
        AdmissionConfig(max_wait_rounds=0)
    with pytest.raises(ValueError):
        AdmissionConfig(max_waiting=0)
    with pytest.raises(ValueError):
        AdmissionConfig(max_groups_per_round=0)
    with pytest.raises(ValueError):
        AdmissionConfig(slo_classes=(SLOClass("x"), SLOClass("x")))
    cfg = AdmissionConfig()
    assert cfg.slo(None).name == "standard"     # default = first class
    assert cfg.slo("interactive").weight == 4.0
    with pytest.raises(KeyError):
        cfg.slo("platinum")


def test_validate_buckets_names_offender():
    """The validation error must blame the argument actually at fault
    (the old message said 'r_buckets' no matter which axis failed)."""
    with pytest.raises(ValueError, match="b_buckets"):
        ServeConfig(b_buckets=(8, 4))
    with pytest.raises(ValueError, match="scene_buckets"):
        ServeConfig(scene_buckets=(512, 256))
    with pytest.raises(ValueError, match="r_buckets"):
        ServeConfig(r_buckets=())
    with pytest.raises(ValueError, match="scene_buckets"):
        SceneRegistry((512, 512))


# --- round planning -------------------------------------------------------

def test_plan_round_drain_mode():
    ctl = AdmissionController(AdmissionConfig(mode="drain"))
    # an in-flight (bound) bucket always wins, regardless of age/order
    d = {A: BucketDemand(pending=2, order=0),
         B: BucketDemand(pending=1, bound=1, order=5)}
    assert ctl.plan_round(d) == [B]
    # nothing bound: the oldest waiting stream's bucket, alone
    d = {A: BucketDemand(pending=2, order=3),
         B: BucketDemand(pending=1, order=1)}
    assert ctl.plan_round(d) == [B]
    assert ctl.plan_round({A: BucketDemand()}) == []


def test_plan_round_mixed_serves_all_pending():
    ctl = AdmissionController(AdmissionConfig())
    d = {A: BucketDemand(pending=1, order=7),
         B: BucketDemand(pending=2, order=2)}
    assert set(ctl.plan_round(d)) == {A, B}     # no cap: everyone renders
    assert ctl.plan_round(d)[0] == B            # oldest-first tiebreak
    # SLO weight outranks arrival order
    d[A].weight = 4.0
    assert ctl.plan_round(d)[0] == A


def test_plan_round_aging_beats_cap():
    cfg = AdmissionConfig(max_wait_rounds=2, max_groups_per_round=1)
    ctl = AdmissionController(cfg)
    d = {A: BucketDemand(pending=5, order=0),
         B: BucketDemand(pending=1, order=9)}
    # round 1: cap 1, A is older -> B is skipped and ages
    plan = ctl.plan_round(d)
    assert plan == [A]
    ctl.note_round(d, plan)
    assert ctl.wait_of(B) == 1
    # round 2: serving A again would push B's wait to 2 = the bound, so
    # aging moves B to the front of the capped plan
    plan = ctl.plan_round(d)
    assert plan == [B]
    ctl.note_round(d, plan)
    assert ctl.wait_of(B) == 0 and ctl.max_wait[B] == 1
    assert ctl.wait_of(A) == 1


def test_per_class_wait_bound_tightens_aging():
    cfg = AdmissionConfig(max_wait_rounds=4, max_groups_per_round=1)
    ctl = AdmissionController(cfg)
    # B carries an interactive stream: its wait bound is 1, so it ages
    # immediately even though the config bound is 4
    d = {A: BucketDemand(pending=5, order=0),
         B: BucketDemand(pending=1, order=9, wait_bound=1)}
    assert ctl.plan_round(d) == [B]


def test_note_round_wait_clock():
    ctl = AdmissionController(AdmissionConfig())
    d = {A: BucketDemand(pending=1)}
    for _ in range(3):
        ctl.note_round(d, [])                   # pending but unserved
    assert ctl.wait_of(A) == 3 and ctl.max_wait[A] == 3
    ctl.note_round(d, [A])                      # served: clock resets
    assert ctl.wait_of(A) == 0 and ctl.max_wait[A] == 3
    ctl.note_round({A: BucketDemand(pending=0)}, [])    # queue emptied
    assert ctl.wait_of(A) == 0
    assert ctl.demand_rounds[A] == 4 and ctl.served_rounds[A] == 1
    rep = ctl.report()
    assert rep["max_wait_rounds"] == 3
    assert rep["per_bucket"][str(A)]["share"] == 0.25


def test_offer_backpressure_counts_deferrals():
    ctl = AdmissionController(AdmissionConfig(max_waiting=2))
    assert ctl.offer(0) and ctl.offer(1)
    assert not ctl.offer(2) and not ctl.offer(5)
    assert ctl.deferred == 2
    unbounded = AdmissionController(AdmissionConfig())
    assert unbounded.offer(10 ** 6)             # no bound: always admit


def test_record_service_and_shares():
    ctl = AdmissionController(AdmissionConfig())
    d = {A: BucketDemand(pending=1), B: BucketDemand(pending=1)}
    ctl.note_round(d, [A])
    ctl.note_round(d, [A, B])
    ctl.record_service(A, 8)
    ctl.record_service(A, 4)
    assert ctl.frames_served[A] == 12
    assert ctl.shares() == {A: 1.0, B: 0.5}
    assert ctl.report()["jain_service"] == pytest.approx(
        round(jain_index([1.0, 0.5]), 4))


# --- replay traffic -------------------------------------------------------

def test_skewed_and_burst_traces():
    trace = skewed_trace(22, skew=10)
    assert [len(r) for r in trace] == [11, 11]
    assert trace[0] == [0] * 10 + [1]           # minority arrives last
    assert skewed_trace(5, skew=10) == [[0] * 5]    # clipped tail
    with pytest.raises(ValueError):
        skewed_trace(5, skew=0)

    trace = burst_trace(8, burst_every=3, burst_size=4, scenes=2)
    assert trace == [[], [], [0, 1, 0, 1], [], [], [0, 1, 0, 1]]
    with pytest.raises(ValueError):
        burst_trace(5, burst_size=0)


def test_replay_traffic_protocol():
    cfg = TrafficConfig(min_frames=4, max_frames=6, seed=3)
    tr = ReplayTraffic([[0, 1], [], [1]], cfg)
    assert not tr.done
    first = tr.arrivals()
    assert [idx for _, idx in first] == [0, 1]
    assert all(p.shape[1:] == (4, 4) and 4 <= p.shape[0] <= 6
               for p, _ in first)
    assert tr.arrivals() == []                  # quiet round
    assert [idx for _, idx in tr.arrivals()] == [1]
    assert tr.done and tr.arrivals() == [] and tr.arrived == 3


# --- cache eviction unit --------------------------------------------------

def test_cache_evict_keys():
    cache = ExecutableCache()
    cache.get((A, 2), lambda: "fa")
    cache.get((B, 2), lambda: "fb")
    cache.get((B, 4), lambda: "fc")
    assert cache.evict_keys(lambda k: k[0] == B) == 2
    assert len(cache) == 1 and (A, 2) in cache and (B, 2) not in cache
    stats = cache.stats()
    assert stats["evicted_keys"] == 2
    assert ("evict", (B, 4)) in cache.log
    assert cache.evict_keys(lambda k: k[0] == B) == 0    # idempotent
    cache.get((A, 2))                           # survivor still cached
    assert stats["per_key_hits"] == {str((A, 2)): 0}


# --- server-integrated satellites -----------------------------------------

def test_server_attach_backpressure(small_scene, small_cam):
    scfg = ServeConfig(slots=1, chunk=2, r_buckets=(8,),
                       admission=AdmissionConfig(max_waiting=1))
    srv = StreamServer(small_scene, small_cam,
                       RenderConfig(window=3, capacity=128), scfg)
    srv.attach(np.asarray(_poses(4)))
    with pytest.raises(AdmissionRejected):
        srv.attach(np.asarray(_poses(4)))
    assert srv.try_attach(np.asarray(_poses(4))) is None
    assert srv.streams_seen == 1                # rejected never counted
    assert srv.admission.deferred == 2
    with pytest.raises(KeyError):
        srv.attach(np.asarray(_poses(4)), slo="platinum")


def test_warmup_accumulates_and_spares_stack_memo(small_scene, small_cam):
    """warmup() must add to warmup_seconds (not overwrite the previous
    bill) and must not push warmup-only scene stacks through the bounded
    ``_stacks`` memo — a mid-serving warmup would otherwise evict the
    in-flight round's stack key."""
    scfg = ServeConfig(slots=2, chunk=2, r_buckets=(8,))
    srv = StreamServer(small_scene, small_cam,
                       RenderConfig(window=3, capacity=128), scfg)
    first = srv.warmup()
    assert first > 0 and srv.warmup_seconds == pytest.approx(first)
    second = srv.warmup()                       # cached: cheap, still billed
    assert srv.warmup_seconds == pytest.approx(first + second)
    assert srv._stacks == {}                    # memo untouched by warmup

    srv.attach(np.asarray(_poses(6)))
    srv.step()                                  # memoizes the live stack
    live = set(srv._stacks)
    assert live
    srv.register_scene(
        structured_scene(jax.random.PRNGKey(9), 600, clutter=0.4))
    srv.step()                                  # re-memoize after register
    live = set(srv._stacks)
    srv.warmup()                                # compile the new bucket too
    assert live <= set(srv._stacks)             # in-flight keys survived
    srv.run(max_rounds=10)


def test_sim_trace_counts_both_drop_paths(small_scene, small_cam):
    """frames_dropped must count deque-evicted rounds AND the report-time
    trim to sim_keep (the old code only counted the former), and
    report() must stay idempotent."""
    scfg = ServeConfig(slots=1, chunk=4, r_buckets=(8,),
                       sim_latency=True, sim_keep=2)
    srv = StreamServer(small_scene, small_cam,
                       RenderConfig(window=3, capacity=128), scfg)
    srv.attach(np.asarray(_poses(8)))           # 2 rounds of 4 frames
    report = srv.run(max_rounds=10)
    sim = report["sim"]
    # round 1 (4 frames) evicted from the 1-round deque; round 2 trimmed
    # from 4 frames to sim_keep=2 at report time
    assert sim["frames"] == 2
    assert sim["frames_dropped"] == 6
    assert srv.report()["sim"]["frames_dropped"] == 6   # idempotent


def test_evict_scene_purges_bucket_executables(small_cam):
    """register -> serve -> evict across two buckets: when the last
    scene of a bucket leaves, its executables (and batcher) go too."""
    reg = SceneRegistry((256, 512))
    big = reg.register(structured_scene(jax.random.PRNGKey(11), 260,
                                        clutter=0.4))
    blob = reg.register(random_blob_scene(jax.random.PRNGKey(12), 90))
    scfg = ServeConfig(slots=1, chunk=2, r_buckets=(8,),
                       scene_buckets=(256, 512))
    srv = StreamServer(reg, small_cam,
                       RenderConfig(window=3, capacity=128), scfg)
    for e in (big, blob):
        srv.attach(np.asarray(_poses(4)), scene_id=e.scene_id)
    report = srv.run(max_rounds=20)
    assert report["streams_finished"] == 2
    assert report["cache"]["distinct_executables"] == 2
    assert set(srv._batchers) == {big.bucket, blob.bucket}

    srv.evict_scene(blob.scene_id)              # bucket (256, 1) empties
    stats = srv.cache.stats()
    assert stats["distinct_executables"] == 1
    assert stats["evicted_keys"] == 1
    assert set(srv._batchers) == {big.bucket}
    # the surviving bucket's executable still serves without recompiling
    misses = srv.cache.misses
    srv.attach(np.asarray(_poses(2)), scene_id=big.scene_id)
    srv.run(max_rounds=10)
    assert srv.cache.misses == misses
