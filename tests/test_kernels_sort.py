"""GSU bitonic-sort kernel vs the argsort oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ref import tile_sort_ref
from repro.kernels.tile_sort import tile_sort_pallas


# The larger networks take minutes-to-hours under Pallas interpret mode on
# CPU: tier-1 keeps the smallest case, tier 2 (-m slow / plain pytest with
# no marker filter) covers the rest.
@pytest.mark.parametrize("t,k", [
    (4, 16),
    pytest.param(8, 64, marks=pytest.mark.slow),
    pytest.param(3, 100, marks=pytest.mark.slow),
    pytest.param(16, 256, marks=pytest.mark.slow),
])
def test_bitonic_matches_argsort(t, k):
    key = jax.random.PRNGKey(t * 1000 + k)
    keys = jax.random.uniform(key, (t, k), minval=0.0, maxval=50.0)
    vals = jnp.tile(jnp.arange(k, dtype=jnp.int32)[None], (t, 1))
    rk, rv = tile_sort_ref(keys, vals)
    pk, pv = tile_sort_pallas(keys, vals)
    np.testing.assert_allclose(np.asarray(pk), np.asarray(rk))
    # permutation validity: sorted keys must match keys[vals]
    np.testing.assert_allclose(
        np.take_along_axis(np.asarray(keys), np.asarray(pv), 1),
        np.asarray(pk))


def test_bitonic_with_inf_padding_keys():
    """binning semantics: invalid entries carry +inf and must sink last."""
    keys = jnp.array([[3.0, jnp.inf, 1.0, jnp.inf],
                      [jnp.inf, 2.0, jnp.inf, 0.5]])
    vals = jnp.arange(4, dtype=jnp.int32)[None].repeat(2, 0)
    pk, pv = tile_sort_pallas(keys, vals)
    assert np.isinf(np.asarray(pk)[:, -2:]).all()
    np.testing.assert_allclose(np.asarray(pk)[0, :2], [1.0, 3.0])
    np.testing.assert_allclose(np.asarray(pk)[1, :2], [0.5, 2.0])
