"""Training-system behaviour: learning, checkpoint/restart, data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.train import RunConfig, train_loop
from repro.train import checkpoint as ckpt
from repro.train.data import DataConfig, batch_at
from repro.train.optimizer import OptimizerConfig
from repro.train import train_step as TS


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("yi-9b").reduced()
    data = DataConfig(batch_size=4, seq_len=64, vocab_size=cfg.vocab_size,
                      seed=3)
    opt = OptimizerConfig(peak_lr=1e-3, warmup_steps=5, total_steps=60)
    return cfg, data, opt


def test_loss_decreases(tiny):
    cfg, data, opt = tiny
    out = train_loop(cfg, data, opt, RunConfig(steps=40, ckpt_dir=None),
                     log=lambda *_: None)
    first = np.mean(out["history"][:5])
    last = np.mean(out["history"][-5:])
    assert last < first - 0.5, (first, last)


def test_checkpoint_restart_is_exact(tiny, tmp_path):
    """Kill-and-resume at step 20 must reproduce the uninterrupted run."""
    cfg, data, opt = tiny
    d1 = str(tmp_path / "a")
    full = train_loop(cfg, data, opt,
                      RunConfig(steps=30, ckpt_every=10, ckpt_dir=d1),
                      log=lambda *_: None)

    d2 = str(tmp_path / "b")
    train_loop(cfg, data, opt, RunConfig(steps=20, ckpt_every=10,
                                         ckpt_dir=d2), log=lambda *_: None)
    resumed = train_loop(cfg, data, opt,
                         RunConfig(steps=30, ckpt_every=10, ckpt_dir=d2),
                         log=lambda *_: None)
    np.testing.assert_allclose(resumed["final_loss"], full["final_loss"],
                               rtol=1e-5)


def test_checkpoint_atomicity(tiny, tmp_path):
    cfg, data, opt = tiny
    state = TS.init_train_state(jax.random.PRNGKey(0), cfg)
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, state)
    ckpt.save(d, 2, state)
    assert ckpt.latest_step(d) == 2
    # no tmp litter after successful saves
    assert not [f for f in os.listdir(d) if f.startswith(".tmp")]
    restored, step, _ = ckpt.restore(d, jax.eval_shape(lambda: state))
    assert step == 2
    a = jax.tree_util.tree_leaves(state.params)[0]
    b = jax.tree_util.tree_leaves(restored.params)[0]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_rejects_mismatched_template(tiny, tmp_path):
    cfg, data, opt = tiny
    state = TS.init_train_state(jax.random.PRNGKey(0), cfg)
    d = str(tmp_path / "ck2")
    ckpt.save(d, 1, state)
    other = get_config("starcoder2-7b").reduced()
    wrong = jax.eval_shape(
        lambda: TS.init_train_state(jax.random.PRNGKey(0), other))
    with pytest.raises((ValueError, KeyError)):
        ckpt.restore(d, wrong)


def test_data_stream_deterministic_and_seekable():
    cfg = DataConfig(batch_size=2, seq_len=16, vocab_size=64, seed=1)
    b1 = batch_at(cfg, 17)
    b2 = batch_at(cfg, 17)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = batch_at(cfg, 18)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    # labels are next-token shifted
    assert b1["tokens"].shape == b1["labels"].shape


def test_checkpoint_prune_keeps_latest(tiny, tmp_path):
    cfg, *_ = tiny
    state = TS.init_train_state(jax.random.PRNGKey(0), cfg)
    d = str(tmp_path / "ck3")
    for s in range(1, 7):
        ckpt.save(d, s, state, keep=3)
    kept = sorted(f for f in os.listdir(d) if f.startswith("step_"))
    assert len(kept) == 3
    assert kept[-1] == "step_00000006"
