"""Per-assigned-architecture smoke tests (deliverable f).

Each arch instantiates its REDUCED config (same family/code path, tiny
dims) and runs: one forward (shapes + finite), one train step (loss
finite, grads flow), one decode step, and prefill->decode consistency for
cache-bearing families.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import model as M
from repro.train.optimizer import OptimizerConfig
from repro.train import train_step as TS

ARCHS = list(ARCH_IDS)


def _batch(cfg, b=2, s=32):
    return {"tokens": jnp.arange(b * s, dtype=jnp.int32).reshape(b, s)
            % cfg.vocab_size,
            "labels": jnp.ones((b, s), jnp.int32)}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits, aux, _ = M.forward(params, batch, cfg)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    state = TS.init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(TS.make_train_step(cfg, OptimizerConfig(warmup_steps=1)))
    batch = _batch(cfg)
    new_state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0.0
    # params actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        state.params, new_state.params)
    assert max(jax.tree_util.tree_leaves(moved)) > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_shapes(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    cache = M.init_cache(cfg, 2, 48)
    toks = jnp.ones((2, 1), jnp.int32)
    logits, c2 = M.decode_step(params, toks, cache, cfg)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert int(c2.index) == 1
    logits2, c3 = M.decode_step(params, toks, c2, cfg)
    assert int(c3.index) == 2
    # cache actually advances the distribution
    assert float(jnp.max(jnp.abs(logits2 - logits))) > 0.0


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if get_config(a).family in
                                  ("dense", "moe")])
def test_prefill_decode_consistency(arch):
    """Token t+1 logits from decode-with-cache == from full forward."""
    cfg = get_config(arch).reduced()
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    b, s = 2, 16
    toks = (jnp.arange(b * s, dtype=jnp.int32).reshape(b, s) * 7)  \
        % cfg.vocab_size
    batch = {"tokens": toks}
    # full forward over s tokens
    logits_full, _, cache = M.forward(params, batch, cfg, build_cache=True)
    # decode token s given cache of first s-1: rebuild cache on s-1 prefix
    batch_prefix = dict(batch, tokens=toks[:, :-1])
    _, _, cache_p = M.forward(params, batch_prefix, cfg, build_cache=True)
    # pad cache seq dim to s
    from repro.train.serve_step import _pad_cache_seq
    cache_p = _pad_cache_seq(cache_p, s)
    logits_dec, _ = M.decode_step(params, toks[:, -1:], cache_p, cfg)
    np.testing.assert_allclose(np.asarray(logits_dec[:, 0]),
                               np.asarray(logits_full[:, -1]),
                               atol=2e-2, rtol=2e-2)


def test_param_counts_match_targets():
    """Analytic parameter counts are in the right ballpark of the names."""
    targets = {"yi-9b": 8.8e9, "starcoder2-7b": 7.2e9,
               "minicpm3-4b": 4.1e9}
    for arch, target in targets.items():
        n = get_config(arch).param_count()
        assert 0.55 * target < n < 1.6 * target, (arch, n, target)
    # MoE: active << total
    cfg = get_config("moonshot-v1-16b-a3b")
    assert cfg.active_param_count() < 0.45 * cfg.param_count()
