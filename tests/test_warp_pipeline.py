"""TWSR / DPES / pipeline behaviour tests (paper Sec. IV, Algo. 1)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import warp as warp_mod
from repro.core.camera import make_camera, look_at
from repro.core.metrics import psnr
from repro.core.pipeline import (RenderConfig, render_full_frame,
                                 render_sparse_frame, render_trajectory)
from repro.scenes.trajectory import dolly_trajectory, orbit_trajectory


@pytest.fixture(scope="module")
def ref_frame(small_scene, small_cam):
    cfg = RenderConfig()
    out, state, rec = jax.jit(render_full_frame, static_argnames="cfg")(
        small_scene, small_cam, cfg=cfg)
    return out, state


def test_identity_warp_is_lossless(ref_frame, small_cam):
    """Warping onto the SAME pose must reproduce covered pixels exactly."""
    out, state = ref_frame
    w = warp_mod.viewpoint_transform(
        state.rgb, state.exp_depth, state.trunc_depth, state.source_mask,
        small_cam, small_cam)
    covered = np.asarray(state.source_mask)
    diff = np.abs(np.asarray(w.rgb) - np.asarray(state.rgb))
    assert float(diff[covered].max()) < 1e-5
    # every source pixel maps to itself -> filled at least where covered
    assert bool(np.all(np.asarray(w.filled)[covered]))


def test_identity_warp_interpolates_everything(ref_frame, small_cam):
    out, state = ref_frame
    cov_frac = float(jnp.mean(state.source_mask.astype(jnp.float32)))
    w = warp_mod.viewpoint_transform(
        state.rgb, state.exp_depth, state.trunc_depth, state.source_mask,
        small_cam, small_cam)
    if cov_frac > 0.95:
        assert int(jnp.sum(w.rerender_tile)) <= small_cam.num_tiles // 4


def test_small_motion_mostly_interpolated(small_scene, small_cam):
    cfg = RenderConfig(window=10)
    poses = dolly_trajectory(3, start=(0.0, -0.3, -2.0),
                             target=(0.0, 0.0, 6.0))
    res = render_trajectory(small_scene, small_cam, poses, cfg)
    rec1 = res.records[1]
    t = small_cam.num_tiles
    # Border tiles of this scene are partially uncovered (low opacity) and
    # legitimately re-render; the covered interior must be warpable.
    assert int(rec1.tiles_interpolated) >= t // 3, \
        "2cm camera step should keep covered tiles warpable"
    assert int(rec1.tiles_interpolated) + int(rec1.active.sum()) == t


def test_sparse_frame_quality(small_scene, small_cam):
    """A warped frame must stay within a few dB of the full render."""
    cfg = RenderConfig(window=10)
    poses = dolly_trajectory(4, start=(0.0, -0.3, -2.0),
                             target=(0.0, 0.0, 6.0))
    res = render_trajectory(small_scene, small_cam, poses, cfg)
    full = jax.jit(render_full_frame, static_argnames="cfg")
    for f in range(1, 4):
        out, _, _ = full(small_scene, small_cam.with_pose(poses[f]), cfg=cfg)
        q = float(psnr(res.frames[f], out.rgb))
        assert q > 24.0, f"frame {f}: psnr {q}"


def test_mask_improves_long_chains(small_scene, small_cam):
    """No-cumulative-error mask (Fig. 7): after many consecutive warps the
    masked variant must not be worse than the unmasked one."""
    poses = dolly_trajectory(8, start=(0.0, -0.3, -2.0),
                             target=(0.0, 0.0, 6.0))
    full = jax.jit(render_full_frame, static_argnames="cfg")

    def final_quality(use_mask):
        cfg = RenderConfig(window=100, use_mask=use_mask)
        res = render_trajectory(small_scene, small_cam, poses, cfg)
        out, _, _ = full(small_scene, small_cam.with_pose(poses[-1]), cfg=cfg)
        return float(psnr(res.frames[-1], out.rgb))

    q_mask = final_quality(True)
    q_nomask = final_quality(False)
    assert q_mask >= q_nomask - 0.3, (q_mask, q_nomask)


def test_dpes_culling_barely_changes_image(small_scene, small_cam):
    poses = dolly_trajectory(3, start=(0.0, -0.3, -2.0),
                             target=(0.0, 0.0, 6.0))
    frames = {}
    pairs = {}
    for use in (True, False):
        cfg = RenderConfig(window=10, use_dpes=use)
        res = render_trajectory(small_scene, small_cam, poses, cfg)
        frames[use] = res.frames[-1]
        pairs[use] = int(res.records[-1].sort_pairs.sum())
    q = float(psnr(frames[True], frames[False]))
    assert q > 30.0, f"DPES changed the image too much: {q} dB"
    assert pairs[True] <= pairs[False]


def test_rerender_capacity_overflow_counted(small_scene, small_cam):
    cfg = RenderConfig(window=10, rerender_capacity=1)
    poses = orbit_trajectory(2, radius=7.0, target=(0.0, 0.0, 6.0))
    res = render_trajectory(small_scene, small_cam, poses, cfg)
    rec = res.records[1]
    # with capacity 1, any additional re-render tiles must be counted
    assert int(rec.active.sum()) <= 1
    assert int(rec.overflow_tiles) >= 0


def test_inpaint_fills_all_holes():
    key = jax.random.PRNGKey(0)
    img = jax.random.uniform(key, (32, 32, 3))
    filled = jnp.ones((32, 32), bool).at[10:14, 10:14].set(False)
    out = warp_mod.inpaint(img, filled, iters=8)
    assert not bool(jnp.isnan(out).any())
    # holes got plausible values (neighbor average stays in range)
    hole = out[10:14, 10:14]
    assert float(hole.min()) >= 0.0 and float(hole.max()) <= 1.0
    # valid pixels untouched
    np.testing.assert_allclose(np.where(np.asarray(filled)[..., None],
                                        np.asarray(out), 0),
                               np.where(np.asarray(filled)[..., None],
                                        np.asarray(img), 0))
