"""Fault-tolerance decision layer + serving loop smoke."""
import pytest

from repro.distributed.fault_tolerance import (FailureKind, Policy,
                                               StepWatchdog, action_for,
                                               classify)


def test_classify_failures():
    assert classify(ValueError("loss is NaN")) == FailureKind.NAN_LOSS
    assert classify(RuntimeError("device lost: slice 3 halted")) \
        == FailureKind.DEVICE_LOST
    assert classify(OSError("no space left")) == FailureKind.CHECKPOINT_IO
    assert classify(TimeoutError("collective timed out")) \
        == FailureKind.STEP_TIMEOUT


def test_every_failure_kind_has_an_action():
    for kind in FailureKind:
        assert len(action_for(kind)) > 10


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(Policy(straggler_grace=2.0))
    for _ in range(10):
        assert not wd.observe(1.0)
    assert wd.observe(5.0)
    assert wd.flagged == 1
    assert not wd.observe(1.1)


def test_serve_loop_smoke():
    from repro.configs import get_config
    from repro.launch.serve import serve

    cfg = get_config("yi-9b").reduced()
    out = serve(cfg, batch_slots=2, max_seq=32, n_requests=3,
                prompt_len=4, max_new=4)
    assert out["requests_done"] >= 1
    assert out["decode_steps"] > 0
