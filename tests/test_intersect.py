"""TAIT properties (paper Sec. IV-C / Fig. 9) + hypothesis fuzzing.

Invariants:
  exact ⊆ TAIT ⊆ TAIT-stage1 ⊆ (3-sigma AABB when opacity <= 1)
  exact ⊆ OBB
  pair counts strictly improve AABB -> OBB -> TAIT toward exact.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import intersect, projection
from repro.core.camera import make_camera, look_at
from repro.core.gaussians import GaussianScene, rgb_to_sh_dc
from repro.scenes.synthetic import structured_scene


def _proj_and_grid(scene, cam):
    proj = projection.preprocess(scene, cam)
    grid = intersect.make_tile_grid(cam)
    return proj, grid


def test_tait_between_exact_and_aabb(small_scene, small_cam):
    proj, grid = _proj_and_grid(small_scene, small_cam)
    m_exact = intersect.exact_mask(proj, grid)
    m_tait = intersect.tait_mask(proj, grid)
    m_s1 = intersect.tait_stage1_mask(proj, grid)
    m_obb = intersect.obb_mask(proj, grid)
    assert bool(jnp.all(m_exact <= m_tait)), "TAIT dropped a true pair"
    assert bool(jnp.all(m_tait <= m_s1)), "stage2 must only remove pairs"
    assert bool(jnp.all(m_exact <= m_obb)), "OBB dropped a true pair"


def test_pair_count_ordering(small_scene, wide_cam):
    proj, grid = _proj_and_grid(small_scene, wide_cam)
    counts = {m: int(intersect.pair_count(intersect.intersect(proj, grid, m)))
              for m in ["aabb", "obb", "tait_stage1", "tait", "exact"]}
    assert counts["exact"] <= counts["tait"] <= counts["tait_stage1"]
    assert counts["tait"] <= counts["aabb"]
    assert counts["exact"] <= counts["obb"] <= counts["aabb"]


def test_elongated_gaussians_benefit_most(small_cam):
    """TAIT's stage-2 is designed for elongated splats (paper Fig. 8)."""
    n = 200
    key = jax.random.PRNGKey(11)
    ks = jax.random.split(key, 5)
    means = jax.random.uniform(ks[0], (n, 3), minval=-2, maxval=2)
    means = means.at[:, 2].add(6.0)
    # strongly anisotropic: one long axis
    log_scales = jnp.stack([
        jax.random.uniform(ks[1], (n,), minval=-1.0, maxval=-0.3),
        jnp.full((n,), -4.0), jnp.full((n,), -4.0)], -1)
    quats = jax.random.normal(ks[2], (n, 4))
    opac = jnp.full((n,), 2.0)
    sh = jnp.zeros((n, 1, 3)).at[:, 0].set(rgb_to_sh_dc(jnp.full((n, 3), .5)))
    scene = GaussianScene(means, log_scales, quats, opac, sh)
    proj, grid = _proj_and_grid(scene, small_cam)
    n_aabb = int(intersect.pair_count(intersect.aabb_mask(proj, grid)))
    n_tait = int(intersect.pair_count(intersect.tait_mask(proj, grid)))
    n_exact = int(intersect.pair_count(intersect.exact_mask(proj, grid)))
    # At 64x64 the tile circumradius (11.3px) bounds stage-2 rejection; the
    # reduction grows with resolution (see benchmarks/intersection.py).
    assert n_tait < 0.7 * n_aabb, (n_tait, n_aabb)
    assert n_tait <= 1.6 * max(n_exact, 1)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.floats(0.1, 0.95),
       st.floats(-4.5, -0.5))
def test_tait_never_drops_true_pairs_fuzz(seed, opac_level, scale_level):
    """Random scenes across opacity/scale regimes keep exact ⊆ TAIT."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    n = 64
    means = jax.random.uniform(ks[0], (n, 3), minval=-2, maxval=2)
    means = means.at[:, 2].add(5.0)
    log_scales = jax.random.uniform(ks[1], (n, 3), minval=scale_level - 0.5,
                                    maxval=scale_level + 0.5)
    quats = jax.random.normal(ks[2], (n, 4))
    logit = jnp.log(opac_level / (1 - opac_level))
    sh = jnp.zeros((n, 1, 3))
    scene = GaussianScene(means, log_scales, quats,
                          jnp.full((n,), logit), sh)
    cam = make_camera(look_at((0., 0., -1.), (0., 0., 5.)),
                      width=64, height=64)
    proj, grid = _proj_and_grid(scene, cam)
    m_exact = intersect.exact_mask(proj, grid)
    m_tait = intersect.tait_mask(proj, grid)
    assert bool(jnp.all(m_exact <= m_tait))


def test_per_tile_counts_match_mask(small_scene, small_cam):
    proj, grid = _proj_and_grid(small_scene, small_cam)
    mask = intersect.tait_mask(proj, grid)
    per_tile = intersect.per_tile_count(mask)
    assert int(per_tile.sum()) == int(intersect.pair_count(mask))
    assert per_tile.shape == (grid.num_tiles,)
