"""Property tests for core/metrics.py (PSNR / SSIM).

Runs under real ``hypothesis`` when installed and under the seeded
deterministic shim otherwise (tests/_hypothesis_compat.py) — either way
each property is checked over a spread of generated images, not one
hand-picked pair.

Pinned contracts:
  - identical images: PSNR hits the mse>=1e-12 clamp (finite, maximal —
    never inf/nan), SSIM == 1 within 1e-6;
  - SSIM is symmetric in its arguments;
  - both metrics degrade monotonically as noise amplitude grows.
"""
import jax
import jax.numpy as jnp
import numpy as np

from _hypothesis_compat import given, settings, st
from repro.core.metrics import psnr, ssim

# Big enough for SSIM's 11x11 valid-mode window, small enough to be fast.
_H = _W = 24


def _image(seed: int) -> jnp.ndarray:
    return jax.random.uniform(jax.random.PRNGKey(seed), (_H, _W, 3))


def _noise(seed: int) -> jnp.ndarray:
    return jax.random.normal(jax.random.PRNGKey(seed + 7919), (_H, _W, 3))


@given(st.integers(min_value=0, max_value=10 ** 6))
@settings(max_examples=5, deadline=None)
def test_psnr_identical_is_max_clamped(seed):
    """psnr(x, x) has mse 0, clamped to 1e-12: exactly 120 dB at
    max_val=1 — finite (never inf/nan), and no other pair beats it."""
    img = _image(seed)
    p = float(psnr(img, img))
    assert np.isfinite(p)
    np.testing.assert_allclose(p, 120.0, atol=1e-4)
    assert float(psnr(img, img + 0.1)) < p


@given(st.integers(min_value=0, max_value=10 ** 6),
       st.floats(min_value=0.5, max_value=4.0))
@settings(max_examples=5, deadline=None)
def test_psnr_max_val_scale(seed, max_val):
    """The clamp ceiling moves with max_val: +20*log10(max_val) dB."""
    img = _image(seed)
    p = float(psnr(img, img, max_val=max_val))
    np.testing.assert_allclose(p, 120.0 + 20.0 * np.log10(max_val),
                               rtol=1e-5)


@given(st.integers(min_value=0, max_value=10 ** 6))
@settings(max_examples=5, deadline=None)
def test_ssim_identical_is_one(seed):
    img = _image(seed)
    np.testing.assert_allclose(float(ssim(img, img)), 1.0, atol=1e-6)


@given(st.integers(min_value=0, max_value=10 ** 6),
       st.integers(min_value=0, max_value=10 ** 6))
@settings(max_examples=5, deadline=None)
def test_ssim_symmetry(seed_a, seed_b):
    a, b = _image(seed_a), _image(seed_b)
    np.testing.assert_allclose(float(ssim(a, b)), float(ssim(b, a)),
                               atol=1e-6)


@given(st.integers(min_value=0, max_value=10 ** 6))
@settings(max_examples=5, deadline=None)
def test_monotone_degradation_under_noise(seed):
    """One noise pattern at growing amplitude: PSNR strictly falls (mse
    grows as a^2) and SSIM falls with it — more corruption never scores
    better."""
    img = _image(seed)
    noise = _noise(seed)
    amps = (0.01, 0.05, 0.2, 0.5)
    psnrs = [float(psnr(img + a * noise, img)) for a in amps]
    ssims = [float(ssim(img + a * noise, img)) for a in amps]
    for lo, hi in zip(psnrs[1:], psnrs[:-1]):
        assert lo < hi
    for lo, hi in zip(ssims[1:], ssims[:-1]):
        assert lo < hi + 1e-6
    assert ssims[-1] < 1.0
