"""Distributed behaviour on 8 host devices (subprocess: device count must
be set before jax init, and the main test process stays single-device)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, timeout=900) -> dict:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(_REPO, "src"),
               JAX_PLATFORMS="cpu")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    line = out.stdout.strip().splitlines()[-1]
    return json.loads(line)


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    r = _run(textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.launch.mesh import make_host_mesh
        from repro.distributed.sharding import param_shardings, batch_shardings
        from repro.train.optimizer import OptimizerConfig
        from repro.train import train_step as TS
        from repro.train.data import DataConfig, batch_at

        cfg = get_config("yi-9b").reduced()
        opt = OptimizerConfig(warmup_steps=1)
        data = DataConfig(batch_size=4, seq_len=64,
                          vocab_size=cfg.vocab_size)
        state = TS.init_train_state(jax.random.PRNGKey(0), cfg)
        batch = batch_at(data, 0)

        # single device reference
        ref_step = jax.jit(TS.make_train_step(cfg, opt))
        _, ref_metrics = ref_step(state, batch)

        mesh = make_host_mesh(4, 2)
        st_sh = param_shardings(state, mesh)
        state_d = jax.device_put(state, st_sh)
        batch_d = jax.device_put(batch, batch_shardings(batch, mesh))
        step = jax.jit(TS.make_train_step(cfg, opt, mesh),
                       in_shardings=(st_sh, batch_shardings(batch, mesh)))
        new_state, metrics = step(state_d, batch_d)
        print(json.dumps({
            "ref_loss": float(ref_metrics["loss"]),
            "sharded_loss": float(metrics["loss"]),
            "ref_gnorm": float(ref_metrics["grad_norm"]),
            "sharded_gnorm": float(metrics["grad_norm"]),
        }))
    """))
    assert abs(r["ref_loss"] - r["sharded_loss"]) < 1e-3, r
    assert abs(r["ref_gnorm"] - r["sharded_gnorm"]) \
        < 1e-2 * max(r["ref_gnorm"], 1), r


@pytest.mark.slow
def test_elastic_remesh_checkpoint():
    """Save on a 4x2 mesh, restore onto 2x4 — loss identical after load."""
    r = _run(textwrap.dedent("""
        import json, tempfile
        import jax
        from repro.configs import get_config
        from repro.launch.mesh import make_host_mesh
        from repro.distributed.sharding import param_shardings
        from repro.train import checkpoint as ckpt
        from repro.train.optimizer import OptimizerConfig
        from repro.train import train_step as TS
        from repro.train.data import DataConfig, batch_at
        from repro.train.train_step import make_loss_fn

        cfg = get_config("starcoder2-7b").reduced()
        data = DataConfig(batch_size=4, seq_len=32,
                          vocab_size=cfg.vocab_size)
        batch = batch_at(data, 0)
        state = TS.init_train_state(jax.random.PRNGKey(0), cfg)

        mesh_a = make_host_mesh(4, 2)
        state_a = jax.device_put(state, param_shardings(state, mesh_a))
        loss_a = float(jax.jit(make_loss_fn(cfg))(
            state_a.params, batch)[0])
        d = tempfile.mkdtemp()
        ckpt.save(d, 1, state_a)

        mesh_b = make_host_mesh(2, 4)
        template = jax.eval_shape(lambda: state)
        sh_b = param_shardings(template, mesh_b)
        state_b, step, _ = ckpt.restore(d, template, shardings=sh_b)
        loss_b = float(jax.jit(make_loss_fn(cfg))(
            state_b.params, batch)[0])
        leaf = jax.tree_util.tree_leaves(state_b.params)[0]
        print(json.dumps({"loss_a": loss_a, "loss_b": loss_b,
                          "resharded": str(leaf.sharding)[:60]}))
    """))
    assert abs(r["loss_a"] - r["loss_b"]) < 1e-5, r


@pytest.mark.slow
def test_int8_compressed_psum_error_feedback():
    """Compressed DP all-reduce: per-step error bounded, bias vanishes
    across steps thanks to error feedback."""
    r = _run(textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.distributed.compression import (compressed_psum,
                                                   zero_residuals)

        mesh = jax.make_mesh((8,), ("data",))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 256)) * 0.1

        def step(x, r):
            return compressed_psum(x, "data", r)

        f = shard_map(step, mesh=mesh,
                      in_specs=(P("data"), P("data")),
                      out_specs=(P("data"), P("data")))
        exact = jnp.mean(g, axis=0)
        res = jnp.zeros_like(g)
        errs = []
        accum_err = jnp.zeros_like(exact)
        for it in range(6):
            mean_g, res = f(g, res)
            err = mean_g[0] - exact
            accum_err = accum_err + err
            errs.append(float(jnp.max(jnp.abs(err))))
        print(json.dumps({
            "per_step_err": errs,
            "accum_err": float(jnp.max(jnp.abs(accum_err))),
            "exact_scale": float(jnp.max(jnp.abs(exact)))}))
    """))
    scale = max(r["exact_scale"], 1e-6)
    assert r["per_step_err"][0] < 0.2 * scale, r
    # error feedback: accumulated bias across 6 steps stays ~one-step sized
    assert r["accum_err"] < 6 * 0.2 * scale, r


@pytest.mark.slow
def test_dryrun_cell_on_host_mesh():
    """The dry-run path end-to-end on a small real mesh (actually runs)."""
    r = _run(textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.launch.mesh import make_host_mesh
        from repro.distributed.sharding import (param_shardings,
                                                batch_shardings)
        from repro.models import model as M

        cfg = get_config("moonshot-v1-16b-a3b").reduced()
        mesh = make_host_mesh(2, 4)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        params = jax.device_put(params, param_shardings(params, mesh))
        batch = {"tokens": jnp.ones((4, 32), jnp.int32)}
        batch = jax.device_put(batch, batch_shardings(batch, mesh))

        def fwd(p, b):
            logits, aux, _ = M.forward(p, b, cfg)
            return logits

        with mesh:
            out = jax.jit(fwd)(params, batch)
        print(json.dumps({"shape": list(out.shape),
                          "finite": bool(jnp.isfinite(out).all())}))
    """))
    assert r["finite"], r
    assert r["shape"][0] == 4
