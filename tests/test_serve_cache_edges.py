"""Edge-case tests for the serving bucket policy (serve/cache.py).

``suggest_capacity`` / ``BucketPolicy.suggest_buckets`` consume recorded
demand that real servers routinely degenerate: no frames yet, only key
frames, all-zero demand, demand past the largest bucket, and the
quantile knob at its 0.0 / 1.0 boundaries. Each of those must map to a
defined bucket, never an exception or an off-list value.
"""
from types import SimpleNamespace

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.serve.cache import (BucketPolicy, pick_capacity, snap_capacity,
                               suggest_buckets, suggest_capacity)

BUCKETS = (8, 16, 32)


def _records(active, overflow, is_full):
    """Minimal stand-in for StackedRecords: the three fields
    suggest_capacity reads, as (F, ...) numpy arrays."""
    return SimpleNamespace(active=np.asarray(active),
                           overflow_tiles=np.asarray(overflow),
                           is_full=np.asarray(is_full))


def _demand_records(demands, tiles=64):
    """Sparse-frame records with the given per-frame demands (as active
    tile counts, no overflow)."""
    f = len(demands)
    active = np.zeros((f, tiles), bool)
    for i, d in enumerate(demands):
        active[i, :d] = True
    return _records(active, np.zeros((f,), np.int32), np.zeros((f,), bool))


# --- empty / degenerate demand -------------------------------------------

def test_empty_records_pick_smallest_bucket():
    rec = _records(np.zeros((0, 64), bool), np.zeros((0,), np.int32),
                   np.zeros((0,), bool))
    assert suggest_capacity(rec, buckets=BUCKETS) == BUCKETS[0]


def test_only_full_frames_pick_smallest_bucket():
    """Key frames re-render everything by definition — they carry no
    demand signal, so an all-full history is the same as no history."""
    rec = _records(np.ones((4, 64), bool), np.zeros((4,), np.int32),
                   np.ones((4,), bool))
    assert suggest_capacity(rec, buckets=BUCKETS) == BUCKETS[0]


def test_frame_mask_can_empty_the_sample():
    rec = _demand_records([40, 50, 60])
    assert suggest_capacity(rec, buckets=BUCKETS,
                            frame_mask=np.zeros((3,), bool)) == BUCKETS[0]
    assert suggest_capacity(rec, buckets=BUCKETS,
                            frame_mask=np.ones((3,), bool)) == BUCKETS[-1]


def test_all_zero_demand_picks_smallest_bucket():
    rec = _demand_records([0, 0, 0])
    assert suggest_capacity(rec, buckets=BUCKETS) == BUCKETS[0]


def test_demand_above_largest_bucket_saturates():
    """Runaway demand snaps to the LARGEST bucket (overflow tiles then
    degrade to interpolation) — it must not raise or extrapolate."""
    rec = _demand_records([64, 64])
    assert suggest_capacity(rec, buckets=BUCKETS) == BUCKETS[-1]
    assert snap_capacity(10 ** 9, BUCKETS) == BUCKETS[-1]


def test_overflow_tiles_count_as_demand():
    """Demand = active + overflow (plan.rerender_demand): 6 active + 20
    dropped tiles must shop for a 26-slot bucket, not a 8-slot one."""
    rec = _records(
        np.concatenate([np.ones((1, 6), bool), np.zeros((1, 58), bool)],
                       axis=1),
        np.asarray([20], np.int32), np.zeros((1,), bool))
    assert suggest_capacity(rec, buckets=BUCKETS) == 32


# --- quantile boundaries --------------------------------------------------

def test_quantile_boundaries():
    rec = _demand_records([2, 12, 31])
    assert suggest_capacity(rec, quantile=0.0, buckets=BUCKETS) == 8
    assert suggest_capacity(rec, quantile=1.0, buckets=BUCKETS) == 32
    # Exactly-on-bucket demand stays in that bucket (<=, not <).
    assert pick_capacity([16], 1.0, BUCKETS) == 16
    assert pick_capacity([17], 1.0, BUCKETS) == 32


def test_policy_rejects_out_of_range_quantile():
    with pytest.raises(ValueError):
        BucketPolicy(quantile=-0.1)
    with pytest.raises(ValueError):
        BucketPolicy(quantile=1.5)
    with pytest.raises(ValueError):
        BucketPolicy(r_buckets=(16, 8))         # must ascend
    with pytest.raises(ValueError):
        BucketPolicy(b_buckets=())              # must be non-empty


# --- the 2-axis suggestion ------------------------------------------------

def test_suggest_buckets_empty_queue_and_records():
    rec = _records(np.zeros((0, 64), bool), np.zeros((0,), np.int32),
                   np.zeros((0,), bool))
    pol = BucketPolicy(b_buckets=(2, 4, 8), r_buckets=BUCKETS)
    assert suggest_buckets(rec, 0, pol) == (2, BUCKETS[0])
    assert suggest_buckets(rec, 10 ** 6, pol) == (8, BUCKETS[0])


@given(st.integers(min_value=0, max_value=200),
       st.floats(min_value=0.0, max_value=1.0))
@settings(max_examples=5, deadline=None)
def test_snap_and_pick_always_land_on_a_bucket(demand, quantile):
    """Whatever the demand and quantile, the answer is a listed bucket
    that covers the demand when any bucket can."""
    snapped = snap_capacity(demand, BUCKETS)
    assert snapped in BUCKETS
    if demand <= BUCKETS[-1]:
        assert snapped >= demand
    picked = pick_capacity([demand], quantile, BUCKETS)
    assert picked == snapped
