"""Measured wall-clock of the jitted pipeline (ours, CPU): full render vs
TWSR sparse frame vs the Pallas-kernel raster stage in isolation, the
dense vs plan-compacted sparse path across re-render ratios (the TilePlan
claim: intersect/bin/sort/raster cost scales with R, not T), plus the
scanned streaming engine (one executable per trajectory) against the
legacy per-frame dispatch loop.

The dense-vs-compacted sweep is also written to
``experiments/artifacts/plan_compaction.json`` (overwritten per run) so
the speedup numbers ride along with the repo, and the
dense-vs-compacted-vs-fused kernel sweep (the ``pallas_fused`` plan-slot
path, DESIGN.md §9) to ``experiments/artifacts/pallas_raster.json``.
On CPU the Pallas rows run in interpret mode — a correctness/shape
record, not a speed claim; the same sweep compiled on TPU is where the
fused path's win is measured."""
from __future__ import annotations

import functools
import json
import os
from typing import List, Sequence

import jax
import numpy as np

from benchmarks.common import camera, scenes, timed, trajectory
from repro.core import binning, intersect, projection
from repro.core.engine import render_streams
from repro.core.metrics import psnr, ssim
from repro.core.pipeline import (RenderConfig, render_full_frame,
                                 render_sparse_frame, render_trajectory,
                                 render_trajectory_py)
from repro.core.plan import rerender_demand
from repro.kernels import ops as kops

N_TRAJ_FRAMES = 8
# Plan slot counts for the compaction sweep (camera has 144 tiles).
PLAN_CAPS = (9, 18, 36, 72)

_ARTIFACT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                         "artifacts", "plan_compaction.json")
_PALLAS_ARTIFACT = os.path.join(os.path.dirname(__file__), "..",
                                "experiments", "artifacts",
                                "pallas_raster.json")
# Fused-sweep sizing: K kept below the RenderConfig default so the
# interpret-mode rows stay minutes-not-hours on CPU; R matches the
# serve-layer's largest default bucket.
FUSED_K = 256
FUSED_R = 32
# Contribution-culling ablation thresholds (DESIGN.md §12): blend mass
# summed over a tile's pixels (so up to ~pixels-per-tile for an opaque
# splat). 0.05 trims only the near-invisible tail; 2.0 removes ~4% of
# the sort workload on the bench orbit while every sparse frame stays
# above 35 dB PSNR vs uncull.
CULL_THRESHOLDS = (0.05, 0.5, 2.0)


def cull_ablation_rows(scene, cam, poses,
                       thresholds: Sequence[float] = CULL_THRESHOLDS, *,
                       window: int = 4, rerender_capacity: int = 36,
                       capacity: int = 256) -> List[dict]:
    """Threshold x quality/work sweep for contribution culling.

    Renders the same trajectory at ``cull_threshold = 0`` (the bit-exact
    reference) and at each nonzero threshold, then reports per-row: mean
    and worst sparse-frame PSNR/SSIM against the uncull frames
    (core/metrics.py), total sort pairs, the sparse-frame re-render
    demand (``plan.rerender_demand`` — the statistic the serve layer's
    ``suggest_capacity`` quantiles), and the culled-pair count. Emitted
    by ``benchmarks/cull_ablation.py`` (also the CI ``--smoke`` entry),
    not by ``run()`` here, so re-running either bench replaces only its
    own rows in bench_results.json.
    """
    f = poses.shape[0]
    per_frame = 1e6 / f

    def run_cfg(th):
        cfg = RenderConfig(window=window, capacity=capacity,
                           rerender_capacity=rerender_capacity,
                           cull_threshold=th)
        res = render_trajectory(scene, cam, poses, cfg)
        # One timed iteration: the rows' headline is quality-vs-work;
        # wall clock rides along without tripling the sweep's cost.
        t_call = timed(lambda: render_trajectory(scene, cam, poses,
                                                 cfg).frames, iters=1)
        return res, t_call

    base, t_base = run_cfg(0.0)
    sparse = ~np.asarray(base.records.is_full)

    def work(res):
        sort_pairs = int(np.asarray(res.records.sort_pairs).sum())
        demand = int(np.asarray(rerender_demand(
            res.records.active, res.records.overflow_tiles))[sparse].sum())
        culled = int(np.asarray(res.records.culled_pairs).sum())
        return sort_pairs, demand, culled

    sp0, dm0, _ = work(base)
    rows = [{"bench": "cull_ablation", "stage": "uncull", "threshold": 0.0,
             "sort_pairs": sp0, "rerender_demand": dm0, "culled_pairs": 0,
             "us_per_call": round(t_base * per_frame, 1),
             "derived": "threshold-0 reference (bit-exact with default)"}]
    for th in thresholds:
        res, t_th = run_cfg(th)
        sp, dm, cl = work(res)
        ps = [float(psnr(res.frames[i], base.frames[i]))
              for i in range(f) if sparse[i]]
        ss = [float(ssim(res.frames[i], base.frames[i]))
              for i in range(f) if sparse[i]]
        rows.append({
            "bench": "cull_ablation", "stage": f"threshold_{th}",
            "threshold": th,
            "psnr_db": round(float(np.mean(ps)), 2),
            "psnr_min_db": round(float(np.min(ps)), 2),
            "ssim": round(float(np.mean(ss)), 4),
            "sort_pairs": sp, "sort_pairs_uncull": sp0,
            "rerender_demand": dm, "rerender_demand_uncull": dm0,
            "culled_pairs": cl,
            "us_per_call": round(t_th * per_frame, 1),
            "derived": f"sparse-frame quality vs uncull; "
                       f"sort_pairs {sp0}->{sp}, demand {dm0}->{dm}"})
    return rows


def _plan_compaction_rows(scene, cam, poses) -> List[dict]:
    """Dense (R = T) vs plan-compacted (R = rerender_capacity) sparse
    frames: same warp, same composition — only the planned slot count
    changes, so the delta is the cost of the T-shaped stages."""
    t = cam.num_tiles
    rows = []

    # One keyframe state shared by every capacity: render_full_frame does
    # not read rerender_capacity, so re-rendering it per rcap would only
    # add redundant jit traces.
    key_cfg = RenderConfig(window=5)
    full_fn = jax.jit(functools.partial(render_full_frame, cfg=key_cfg))
    _, state, _ = full_fn(scene, cam.with_pose(poses[0]))

    def sparse_time(rcap):
        cfg = RenderConfig(window=5, rerender_capacity=rcap)
        fn = jax.jit(functools.partial(render_sparse_frame, cfg=cfg))
        return timed(lambda: fn(scene, cam.with_pose(poses[0]),
                                cam.with_pose(poses[1]), state))

    t_dense = sparse_time(None)
    rows.append({"bench": "plan_compaction", "stage": "sparse_dense",
                 "plan_slots": t, "rerender_ratio": 1.0,
                 "us_per_call": round(t_dense * 1e6, 1),
                 "derived": "R=T reference"})
    for rcap in PLAN_CAPS:
        t_r = sparse_time(rcap)
        rows.append({
            "bench": "plan_compaction", "stage": f"sparse_plan_r{rcap}",
            "plan_slots": rcap, "rerender_ratio": round(rcap / t, 3),
            "us_per_call": round(t_r * 1e6, 1),
            "derived": f"speedup={t_dense / t_r:.2f}x vs dense"})
    return rows


def _pallas_raster_rows(scene, cam, poses) -> List[dict]:
    """Dense vs plan-compacted vs fused-kernel sparse frames, plus the
    raster stage isolated over identical bins for every impl.

    Three frame rows tell the story the paper's accelerator makes on
    hardware: the dense path pays T-shaped stages, the compacted plan
    pays R-shaped stages, and the fused kernel additionally folds the
    GSU sort into the raster pass (one VMEM residency per slot)."""
    t = cam.num_tiles
    rows = []

    # -- raster stage isolated: identical (T, K) bins through each impl --
    proj = projection.preprocess(scene, cam)
    grid = intersect.make_tile_grid(cam)
    mask = intersect.tait_mask(proj, grid)
    bins = binning.build_tile_bins(mask, proj.depth, FUSED_K)
    tg = binning.gather_tiles(proj, bins)
    args = (tg.mean2d, tg.conic, tg.rgb, tg.opacity, tg.depth,
            grid.origins, bins.count)
    for impl in ("jnp_chunked", "pallas", "pallas_fused"):
        t_call = timed(functools.partial(kops.raster_tiles, impl=impl), *args)
        rows.append({
            "bench": "pallas_raster", "stage": f"raster_stage_{impl}",
            "plan_slots": t, "capacity": FUSED_K,
            "us_per_call": round(t_call * 1e6, 1),
            "derived": "interpret-mode on CPU"
            if impl.startswith("pallas") else ""})

    # -- planned sparse frames: dense / compacted / compacted+fused ------
    key_cfg = RenderConfig(window=5, capacity=FUSED_K, impl="jnp_chunked")
    full_fn = jax.jit(functools.partial(render_full_frame, cfg=key_cfg))
    _, state, _ = full_fn(scene, cam.with_pose(poses[0]))

    def sparse_time(rcap, impl):
        cfg = RenderConfig(window=5, capacity=FUSED_K,
                           rerender_capacity=rcap, impl=impl)
        fn = jax.jit(functools.partial(render_sparse_frame, cfg=cfg))
        return timed(lambda: fn(scene, cam.with_pose(poses[0]),
                                cam.with_pose(poses[1]), state))

    t_dense = sparse_time(None, "jnp_chunked")
    t_comp = sparse_time(FUSED_R, "jnp_chunked")
    t_fused = sparse_time(FUSED_R, "pallas_fused")
    for stage, slots, t_call, derived in (
            ("sparse_dense", t, t_dense, "R=T reference, jnp_chunked"),
            ("sparse_compacted", FUSED_R, t_comp,
             f"speedup={t_dense / t_comp:.2f}x vs dense, jnp_chunked"),
            ("sparse_fused", FUSED_R, t_fused,
             f"pallas_fused (interpret on CPU), "
             f"{t_dense / t_fused:.2f}x vs dense")):
        rows.append({
            "bench": "pallas_raster", "stage": stage, "plan_slots": slots,
            "capacity": FUSED_K, "us_per_call": round(t_call * 1e6, 1),
            "derived": derived})
    return rows


def run() -> List[dict]:
    cam = camera()
    scene = scenes()["indoor"]
    poses = trajectory("indoor", 3)
    cfg = RenderConfig(window=5, rerender_capacity=32)
    rows = []

    full_fn = jax.jit(functools.partial(render_full_frame, cfg=cfg))
    t_full = timed(lambda: full_fn(scene, cam.with_pose(poses[0])))
    rows.append({"bench": "wallclock", "stage": "full_frame",
                 "us_per_call": round(t_full * 1e6, 1), "derived": ""})

    _, state, _ = full_fn(scene, cam.with_pose(poses[0]))
    sparse_fn = jax.jit(functools.partial(render_sparse_frame, cfg=cfg))
    t_sparse = timed(lambda: sparse_fn(
        scene, cam.with_pose(poses[0]), cam.with_pose(poses[1]), state))
    rows.append({"bench": "wallclock", "stage": "sparse_frame",
                 "us_per_call": round(t_sparse * 1e6, 1),
                 "derived": f"speedup={t_full / t_sparse:.2f}x"})

    # dense vs plan-compacted sparse frames across re-render ratios
    plan_rows = _plan_compaction_rows(scene, cam, poses)
    rows.extend(plan_rows)
    os.makedirs(os.path.dirname(_ARTIFACT), exist_ok=True)
    with open(_ARTIFACT, "w") as f:
        json.dump(plan_rows, f, indent=1)

    # dense vs compacted vs fused-kernel sweep (DESIGN.md §9)
    fused_rows = _pallas_raster_rows(scene, cam, poses)
    rows.extend(fused_rows)
    with open(_PALLAS_ARTIFACT, "w") as f:
        json.dump(fused_rows, f, indent=1)

    # (The isolated raster stage now lives in _pallas_raster_rows, which
    # sweeps all three impls over identical bins — no duplicate timing.)

    # scanned engine (one executable, stacked records) vs the legacy
    # per-frame dispatch loop — the "no host roundtrips" claim in numbers.
    poses_t = trajectory("indoor", N_TRAJ_FRAMES)
    t_py = timed(lambda: render_trajectory_py(scene, cam, poses_t,
                                              cfg).frames)
    t_scan = timed(lambda: render_trajectory(scene, cam, poses_t,
                                             cfg).frames)
    per_frame = 1e6 / N_TRAJ_FRAMES
    rows.append({"bench": "wallclock", "stage": "trajectory_py_loop",
                 "us_per_call": round(t_py * per_frame, 1),
                 "derived": f"{N_TRAJ_FRAMES}-frame loop, per frame"})
    rows.append({"bench": "wallclock", "stage": "trajectory_scan",
                 "us_per_call": round(t_scan * per_frame, 1),
                 "derived": f"speedup={t_py / t_scan:.2f}x vs py loop"})

    # batched multi-stream serving: 4 staggered streams in one vmap
    import jax.numpy as jnp
    poses_b = jnp.stack([poses_t] * 4)
    t_streams = timed(lambda: render_streams(scene, cam, poses_b,
                                             cfg).frames)
    rows.append({"bench": "wallclock", "stage": "trajectory_streams_b4",
                 "us_per_call": round(t_streams * per_frame / 4, 1),
                 "derived": "per stream-frame, B=4 vmap"})
    return rows
