"""Measured wall-clock of the jitted pipeline (ours, CPU): full render vs
TWSR sparse frame vs the Pallas-kernel raster stage in isolation, the
dense vs plan-compacted sparse path across re-render ratios (the TilePlan
claim: intersect/bin/sort/raster cost scales with R, not T), plus the
scanned streaming engine (one executable per trajectory) against the
legacy per-frame dispatch loop.

The dense-vs-compacted sweep is also written to
``experiments/artifacts/plan_compaction.json`` (overwritten per run) so
the speedup numbers ride along with the repo, and the
dense-vs-compacted-vs-fused kernel sweep (the ``pallas_fused`` plan-slot
path, DESIGN.md §9) to ``experiments/artifacts/pallas_raster.json``.
On CPU the Pallas rows run in interpret mode — a correctness/shape
record, not a speed claim; the same sweep compiled on TPU is where the
fused path's win is measured."""
from __future__ import annotations

import functools
import json
import os
from typing import List

import jax

from benchmarks.common import camera, scenes, timed, trajectory
from repro.core import binning, intersect, projection
from repro.core.engine import render_streams
from repro.core.pipeline import (RenderConfig, render_full_frame,
                                 render_sparse_frame, render_trajectory,
                                 render_trajectory_py)
from repro.kernels import ops as kops

N_TRAJ_FRAMES = 8
# Plan slot counts for the compaction sweep (camera has 144 tiles).
PLAN_CAPS = (9, 18, 36, 72)

_ARTIFACT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                         "artifacts", "plan_compaction.json")
_PALLAS_ARTIFACT = os.path.join(os.path.dirname(__file__), "..",
                                "experiments", "artifacts",
                                "pallas_raster.json")
# Fused-sweep sizing: K kept below the RenderConfig default so the
# interpret-mode rows stay minutes-not-hours on CPU; R matches the
# serve-layer's largest default bucket.
FUSED_K = 256
FUSED_R = 32


def _plan_compaction_rows(scene, cam, poses) -> List[dict]:
    """Dense (R = T) vs plan-compacted (R = rerender_capacity) sparse
    frames: same warp, same composition — only the planned slot count
    changes, so the delta is the cost of the T-shaped stages."""
    t = cam.num_tiles
    rows = []

    # One keyframe state shared by every capacity: render_full_frame does
    # not read rerender_capacity, so re-rendering it per rcap would only
    # add redundant jit traces.
    key_cfg = RenderConfig(window=5)
    full_fn = jax.jit(functools.partial(render_full_frame, cfg=key_cfg))
    _, state, _ = full_fn(scene, cam.with_pose(poses[0]))

    def sparse_time(rcap):
        cfg = RenderConfig(window=5, rerender_capacity=rcap)
        fn = jax.jit(functools.partial(render_sparse_frame, cfg=cfg))
        return timed(lambda: fn(scene, cam.with_pose(poses[0]),
                                cam.with_pose(poses[1]), state))

    t_dense = sparse_time(None)
    rows.append({"bench": "plan_compaction", "stage": "sparse_dense",
                 "plan_slots": t, "rerender_ratio": 1.0,
                 "us_per_call": round(t_dense * 1e6, 1),
                 "derived": "R=T reference"})
    for rcap in PLAN_CAPS:
        t_r = sparse_time(rcap)
        rows.append({
            "bench": "plan_compaction", "stage": f"sparse_plan_r{rcap}",
            "plan_slots": rcap, "rerender_ratio": round(rcap / t, 3),
            "us_per_call": round(t_r * 1e6, 1),
            "derived": f"speedup={t_dense / t_r:.2f}x vs dense"})
    return rows


def _pallas_raster_rows(scene, cam, poses) -> List[dict]:
    """Dense vs plan-compacted vs fused-kernel sparse frames, plus the
    raster stage isolated over identical bins for every impl.

    Three frame rows tell the story the paper's accelerator makes on
    hardware: the dense path pays T-shaped stages, the compacted plan
    pays R-shaped stages, and the fused kernel additionally folds the
    GSU sort into the raster pass (one VMEM residency per slot)."""
    t = cam.num_tiles
    rows = []

    # -- raster stage isolated: identical (T, K) bins through each impl --
    proj = projection.preprocess(scene, cam)
    grid = intersect.make_tile_grid(cam)
    mask = intersect.tait_mask(proj, grid)
    bins = binning.build_tile_bins(mask, proj.depth, FUSED_K)
    tg = binning.gather_tiles(proj, bins)
    args = (tg.mean2d, tg.conic, tg.rgb, tg.opacity, tg.depth,
            grid.origins, bins.count)
    for impl in ("jnp_chunked", "pallas", "pallas_fused"):
        t_call = timed(functools.partial(kops.raster_tiles, impl=impl), *args)
        rows.append({
            "bench": "pallas_raster", "stage": f"raster_stage_{impl}",
            "plan_slots": t, "capacity": FUSED_K,
            "us_per_call": round(t_call * 1e6, 1),
            "derived": "interpret-mode on CPU"
            if impl.startswith("pallas") else ""})

    # -- planned sparse frames: dense / compacted / compacted+fused ------
    key_cfg = RenderConfig(window=5, capacity=FUSED_K, impl="jnp_chunked")
    full_fn = jax.jit(functools.partial(render_full_frame, cfg=key_cfg))
    _, state, _ = full_fn(scene, cam.with_pose(poses[0]))

    def sparse_time(rcap, impl):
        cfg = RenderConfig(window=5, capacity=FUSED_K,
                           rerender_capacity=rcap, impl=impl)
        fn = jax.jit(functools.partial(render_sparse_frame, cfg=cfg))
        return timed(lambda: fn(scene, cam.with_pose(poses[0]),
                                cam.with_pose(poses[1]), state))

    t_dense = sparse_time(None, "jnp_chunked")
    t_comp = sparse_time(FUSED_R, "jnp_chunked")
    t_fused = sparse_time(FUSED_R, "pallas_fused")
    for stage, slots, t_call, derived in (
            ("sparse_dense", t, t_dense, "R=T reference, jnp_chunked"),
            ("sparse_compacted", FUSED_R, t_comp,
             f"speedup={t_dense / t_comp:.2f}x vs dense, jnp_chunked"),
            ("sparse_fused", FUSED_R, t_fused,
             f"pallas_fused (interpret on CPU), "
             f"{t_dense / t_fused:.2f}x vs dense")):
        rows.append({
            "bench": "pallas_raster", "stage": stage, "plan_slots": slots,
            "capacity": FUSED_K, "us_per_call": round(t_call * 1e6, 1),
            "derived": derived})
    return rows


def run() -> List[dict]:
    cam = camera()
    scene = scenes()["indoor"]
    poses = trajectory("indoor", 3)
    cfg = RenderConfig(window=5, rerender_capacity=32)
    rows = []

    full_fn = jax.jit(functools.partial(render_full_frame, cfg=cfg))
    t_full = timed(lambda: full_fn(scene, cam.with_pose(poses[0])))
    rows.append({"bench": "wallclock", "stage": "full_frame",
                 "us_per_call": round(t_full * 1e6, 1), "derived": ""})

    _, state, _ = full_fn(scene, cam.with_pose(poses[0]))
    sparse_fn = jax.jit(functools.partial(render_sparse_frame, cfg=cfg))
    t_sparse = timed(lambda: sparse_fn(
        scene, cam.with_pose(poses[0]), cam.with_pose(poses[1]), state))
    rows.append({"bench": "wallclock", "stage": "sparse_frame",
                 "us_per_call": round(t_sparse * 1e6, 1),
                 "derived": f"speedup={t_full / t_sparse:.2f}x"})

    # dense vs plan-compacted sparse frames across re-render ratios
    plan_rows = _plan_compaction_rows(scene, cam, poses)
    rows.extend(plan_rows)
    os.makedirs(os.path.dirname(_ARTIFACT), exist_ok=True)
    with open(_ARTIFACT, "w") as f:
        json.dump(plan_rows, f, indent=1)

    # dense vs compacted vs fused-kernel sweep (DESIGN.md §9)
    fused_rows = _pallas_raster_rows(scene, cam, poses)
    rows.extend(fused_rows)
    with open(_PALLAS_ARTIFACT, "w") as f:
        json.dump(fused_rows, f, indent=1)

    # (The isolated raster stage now lives in _pallas_raster_rows, which
    # sweeps all three impls over identical bins — no duplicate timing.)

    # scanned engine (one executable, stacked records) vs the legacy
    # per-frame dispatch loop — the "no host roundtrips" claim in numbers.
    poses_t = trajectory("indoor", N_TRAJ_FRAMES)
    t_py = timed(lambda: render_trajectory_py(scene, cam, poses_t,
                                              cfg).frames)
    t_scan = timed(lambda: render_trajectory(scene, cam, poses_t,
                                             cfg).frames)
    per_frame = 1e6 / N_TRAJ_FRAMES
    rows.append({"bench": "wallclock", "stage": "trajectory_py_loop",
                 "us_per_call": round(t_py * per_frame, 1),
                 "derived": f"{N_TRAJ_FRAMES}-frame loop, per frame"})
    rows.append({"bench": "wallclock", "stage": "trajectory_scan",
                 "us_per_call": round(t_scan * per_frame, 1),
                 "derived": f"speedup={t_py / t_scan:.2f}x vs py loop"})

    # batched multi-stream serving: 4 staggered streams in one vmap
    import jax.numpy as jnp
    poses_b = jnp.stack([poses_t] * 4)
    t_streams = timed(lambda: render_streams(scene, cam, poses_b,
                                             cfg).frames)
    rows.append({"bench": "wallclock", "stage": "trajectory_streams_b4",
                 "us_per_call": round(t_streams * per_frame / 4, 1),
                 "derived": "per stream-frame, B=4 vmap"})
    return rows
