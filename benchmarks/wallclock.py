"""Measured wall-clock of the jitted pipeline (ours, CPU): full render vs
TWSR sparse frame vs the Pallas-kernel raster stage in isolation, plus the
scanned streaming engine (one executable per trajectory) against the
legacy per-frame dispatch loop."""
from __future__ import annotations

import functools
from typing import List

import jax

from benchmarks.common import camera, scenes, timed, trajectory
from repro.core import binning, intersect, projection
from repro.core.engine import render_streams
from repro.core.pipeline import (RenderConfig, render_full_frame,
                                 render_sparse_frame, render_trajectory,
                                 render_trajectory_py)
from repro.kernels import ops as kops

N_TRAJ_FRAMES = 8


def run() -> List[dict]:
    cam = camera()
    scene = scenes()["indoor"]
    poses = trajectory("indoor", 3)
    cfg = RenderConfig(window=5, rerender_capacity=32)
    rows = []

    full_fn = jax.jit(functools.partial(render_full_frame, cfg=cfg))
    t_full = timed(lambda: full_fn(scene, cam.with_pose(poses[0])))
    rows.append({"bench": "wallclock", "stage": "full_frame",
                 "us_per_call": round(t_full * 1e6, 1), "derived": ""})

    _, state, _ = full_fn(scene, cam.with_pose(poses[0]))
    sparse_fn = jax.jit(functools.partial(render_sparse_frame, cfg=cfg))
    t_sparse = timed(lambda: sparse_fn(
        scene, cam.with_pose(poses[0]), cam.with_pose(poses[1]), state))
    rows.append({"bench": "wallclock", "stage": "sparse_frame",
                 "us_per_call": round(t_sparse * 1e6, 1),
                 "derived": f"speedup={t_full / t_sparse:.2f}x"})

    # isolated raster stage via bins (jnp_chunked vs pallas-interpret)
    proj = projection.preprocess(scene, cam)
    grid = intersect.make_tile_grid(cam)
    mask = intersect.tait_mask(proj, grid)
    bins = binning.build_tile_bins(mask, proj.depth, cfg.capacity)
    tg = binning.gather_tiles(proj, bins)
    args = (tg.mean2d, tg.conic, tg.rgb, tg.opacity, tg.depth,
            grid.origins, bins.count)
    for impl in ("jnp_chunked", "pallas"):
        t = timed(functools.partial(kops.raster_tiles, impl=impl), *args)
        rows.append({"bench": "wallclock", "stage": f"raster_{impl}",
                     "us_per_call": round(t * 1e6, 1),
                     "derived": "interpret-mode" if impl == "pallas" else ""})

    # scanned engine (one executable, stacked records) vs the legacy
    # per-frame dispatch loop — the "no host roundtrips" claim in numbers.
    poses_t = trajectory("indoor", N_TRAJ_FRAMES)
    t_py = timed(lambda: render_trajectory_py(scene, cam, poses_t,
                                              cfg).frames)
    t_scan = timed(lambda: render_trajectory(scene, cam, poses_t,
                                             cfg).frames)
    per_frame = 1e6 / N_TRAJ_FRAMES
    rows.append({"bench": "wallclock", "stage": "trajectory_py_loop",
                 "us_per_call": round(t_py * per_frame, 1),
                 "derived": f"{N_TRAJ_FRAMES}-frame loop, per frame"})
    rows.append({"bench": "wallclock", "stage": "trajectory_scan",
                 "us_per_call": round(t_scan * per_frame, 1),
                 "derived": f"speedup={t_py / t_scan:.2f}x vs py loop"})

    # batched multi-stream serving: 4 staggered streams in one vmap
    import jax.numpy as jnp
    poses_b = jnp.stack([poses_t] * 4)
    t_streams = timed(lambda: render_streams(scene, cam, poses_b,
                                             cfg).frames)
    rows.append({"bench": "wallclock", "stage": "trajectory_streams_b4",
                 "us_per_call": round(t_streams * per_frame / 4, 1),
                 "derived": "per stream-frame, B=4 vmap"})
    return rows
