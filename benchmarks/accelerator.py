"""Figs. 14/15a + Table I reproduction: the streaming accelerator.

Drives the discrete-event simulator (core/streaming.py) with REAL per-
frame workload records from the rendered pipeline (not synthetic loads).

Configurations:
  gpu_like    : dynamic scheduler, raw workloads, no streaming — the
                Jetson-GPU stand-in the speedups are measured against.
  gscore_like : dedicated units (streaming across frames), round-robin
                blocks, raw workloads           (Fig. 14 "GSCore")
  +LD1        : + LDU inter-block balancing on DPES predictions
  +LD2 (full) : + light-to-heavy intra-block order (LS-Gaussian)
  recorded    : the device-LDU schedule the jitted engine recorded in
                each FrameRecord, served as-is
                (``simulate_sequence(policy="recorded")``) — vs the
                host re-derivation of "ls_gaussian". The jnp LDU is
                pinned bit-identical to the numpy reference
                (tests/test_load_balance.py), so the emitted deltas
                must be ~0; a drift here means the on-device schedule
                no longer matches the host ablations.

Table I = raster-core utilization of gscore_like vs full LS-Gaussian.
"""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import camera, scenes, trajectory
from repro.core.pipeline import RenderConfig, render_trajectory
from repro.core.streaming import AcceleratorConfig, frameworks_from_stacked, \
    simulate_sequence, throughput

N_FRAMES = 12

MODES = {
    "gpu_like": dict(policy="dynamic", workload_source="raw",
                     light_to_heavy=False, streaming=False),
    "gscore_like": dict(policy="round_robin", workload_source="raw",
                        light_to_heavy=False, streaming=True),
    "ld1": dict(policy="ls_gaussian", workload_source="dpes",
                light_to_heavy=False, streaming=True),
    "ls_gaussian": dict(policy="ls_gaussian", workload_source="dpes",
                        light_to_heavy=True, streaming=True),
}


def run() -> List[dict]:
    # Tab. I measures RASTER-phase utilization under real per-tile skew:
    # full frames (window=1 — the paper's utilization table predates the
    # sparse-rendering savings), higher resolution, clutter-heavy scenes
    # (Fig. 5's order-of-magnitude tile-load spread).
    cam = camera(256, 256)
    acfg = AcceleratorConfig(num_blocks=32)
    rows = []
    for scene_name in ("indoor", "outdoor", "synthetic"):
        scene = scenes(6000)[scene_name]
        poses = trajectory("indoor" if scene_name != "outdoor" else
                           "outdoor", N_FRAMES)
        res = render_trajectory(scene, cam, poses, RenderConfig(window=1))
        frames = frameworks_from_stacked(res.records, cam.tiles_x,
                                         cam.tiles_y,
                                         cam.width * cam.height)
        base_cycles = None
        host = None
        for mode, kw in MODES.items():
            t = throughput(simulate_sequence(frames, acfg, **kw),
                           acfg.num_blocks)
            if base_cycles is None:
                base_cycles = t["cycles_per_frame"]
            if mode == "ls_gaussian":
                host = t
            rows.append({
                "bench": "fig14_15_accelerator", "scene": scene_name,
                "mode": mode,
                "cycles_per_frame": int(t["cycles_per_frame"]),
                "speedup_vs_gpu_like": round(
                    base_cycles / t["cycles_per_frame"], 2),
                "utilization_pct": round(100 * t["utilization"], 1),
                "sort_stall": int(t["sort_stall"]),
            })
        # Recorded-vs-host: serve the engine's own device-LDU schedule and
        # report the delta against the host-side "ls_gaussian" derivation.
        rec = throughput(
            simulate_sequence(frames, acfg, policy="recorded"),
            acfg.num_blocks)
        rows.append({
            "bench": "fig14_15_accelerator", "scene": scene_name,
            "mode": "recorded",
            "cycles_per_frame": int(rec["cycles_per_frame"]),
            "speedup_vs_gpu_like": round(
                base_cycles / rec["cycles_per_frame"], 2),
            "utilization_pct": round(100 * rec["utilization"], 1),
            "sort_stall": int(rec["sort_stall"]),
            "cycles_delta_vs_host_pct": round(
                100.0 * (rec["cycles_per_frame"] - host["cycles_per_frame"])
                / host["cycles_per_frame"], 4),
            "utilization_delta_vs_host_pct": round(
                100.0 * (rec["utilization"] - host["utilization"]), 4),
        })
    return rows
