"""Fig. 9 reproduction: Gaussian-tile pairs + preprocess cost per test.

Columns per (scene x method): admitted pairs (vs exact lower bound), and
wall time of projection+intersection (the preprocessing stage the paper
accelerates with TAIT's sqrt/log CCU instead of GSCore's dual OIUs)."""
from __future__ import annotations

import functools
from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import camera, scenes, timed
from repro.core import intersect, projection

METHODS = ("aabb", "obb", "tait_stage1", "tait", "exact")


def run() -> List[dict]:
    cam = camera()
    grid = intersect.make_tile_grid(cam)
    rows = []
    for scene_name, scene in scenes().items():
        proj = projection.preprocess(scene, cam)

        @functools.partial(jax.jit, static_argnames="method")
        def pairs_fn(scene_arg, method):
            pr = projection.preprocess(scene_arg, cam)
            return intersect.pair_count(
                intersect.intersect(pr, grid, method))

        exact = int(pairs_fn(scene, "exact"))
        for m in METHODS:
            n_pairs = int(pairs_fn(scene, m))
            t = timed(functools.partial(pairs_fn, method=m), scene)
            rows.append({
                "bench": "fig9_intersection", "scene": scene_name,
                "method": m, "pairs": n_pairs,
                "pairs_over_exact": round(n_pairs / max(exact, 1), 3),
                "us_per_call": round(t * 1e6, 1),
            })
    return rows
