"""Contribution-culling ablation bench (DESIGN.md §12).

``run()`` (the ``benchmarks.run`` entry) sweeps ``cull_threshold`` over
the standard bench scene/trajectory and emits one row per threshold —
sparse-frame PSNR/SSIM against the uncull render, total sort pairs,
re-render demand, culled pairs, and wall clock — next to a threshold-0
reference row. The sweep itself lives in
``benchmarks.wallclock.cull_ablation_rows`` so it shares the wallclock
harness (scenes, timing) while keeping its own ``bench`` key: re-running
``--only cull_ablation`` replaces exactly these rows in
experiments/artifacts/bench_results.json.

``python -m benchmarks.cull_ablation --smoke`` is the CI entry: a
scoped-down single-threshold pass that asserts the culling contract —
every sparse frame >= 30 dB PSNR vs uncull, sort_pairs strictly
decreased, pairs actually culled, and demand not increased.
"""
from __future__ import annotations

import argparse
from typing import List

from benchmarks.common import camera, scenes, trajectory
from benchmarks.wallclock import CULL_THRESHOLDS, cull_ablation_rows

N_FRAMES = 8
SMOKE_THRESHOLD = 0.05


def run() -> List[dict]:
    cam = camera()
    scene = scenes()["indoor"]
    # The orbit trajectory disoccludes every frame, so sparse frames
    # carry real re-render demand — the slow indoor dolly warps cleanly
    # at bench resolution and would leave the cull nothing to do.
    poses = trajectory("orbit", N_FRAMES)
    return cull_ablation_rows(scene, cam, poses, CULL_THRESHOLDS)


def smoke() -> List[dict]:
    """Small-scene single-threshold pass with hard assertions (CI)."""
    cam = camera(96, 96)
    scene = scenes(1500)["indoor"]
    poses = trajectory("indoor", 6)
    rows = cull_ablation_rows(scene, cam, poses, (SMOKE_THRESHOLD,),
                              window=3, rerender_capacity=18, capacity=128)
    base, row = rows[0], rows[-1]
    assert row["psnr_min_db"] >= 30.0, \
        f"sparse-frame PSNR fell below 30 dB vs uncull: {row}"
    assert row["sort_pairs"] < base["sort_pairs"], \
        f"culling did not reduce sort pairs: {row}"
    assert row["culled_pairs"] > 0, f"nothing was culled: {row}"
    assert row["rerender_demand"] <= base["rerender_demand"], \
        f"culling increased re-render demand: {row}"
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="scoped-down pass with hard assertions (CI)")
    args = ap.parse_args()
    rows = smoke() if args.smoke else run()
    for r in rows:
        print(",".join(f"{k}={v}" for k, v in r.items()), flush=True)
    if args.smoke:
        print("# cull_ablation smoke OK", flush=True)


if __name__ == "__main__":
    main()
