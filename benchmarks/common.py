"""Shared benchmark scenes/trajectories + record->simulator conversion.

Scenes mirror the paper's split: "indoor"-like (flat, view-consistent,
low clutter — playroom/drjohnson analogues) vs "outdoor"-like (high
clutter, depth edges — train/truck/garden analogues), plus Synthetic-NeRF
style blobs. Trajectories follow the paper's 90 FPS / 1.8 m/s / 90 deg/s
setup (scenes/trajectory.py).
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.camera import TILE, make_camera
from repro.core.pipeline import StackedRecords
from repro.core.streaming import FrameWork, frameworks_from_stacked
from repro.scenes.synthetic import random_blob_scene, structured_scene
from repro.scenes.trajectory import dolly_trajectory, orbit_trajectory

IMG = 192  # 12x12 tiles — CPU-friendly while far above toy size


def scenes(n: int = 3000) -> Dict[str, object]:
    key = jax.random.PRNGKey(42)
    return {
        "indoor": structured_scene(key, n, clutter=0.25),
        "outdoor": structured_scene(jax.random.fold_in(key, 1), n,
                                    clutter=0.8),
        "synthetic": random_blob_scene(jax.random.fold_in(key, 2), n),
    }


def camera(width: int = IMG, height: int = IMG):
    return make_camera(jnp.eye(4), width=width, height=height)


def trajectory(kind: str, n_frames: int):
    if kind == "indoor":
        return dolly_trajectory(n_frames, start=(0.0, -0.3, -3.0),
                                target=(0.0, 0.0, 6.0))
    return orbit_trajectory(n_frames, radius=7.0, target=(0.0, 0.0, 6.0))


def records_to_framework(records, tiles_x: int, tiles_y: int,
                         n_pixels: int) -> List[FrameWork]:
    """Trajectory records -> simulator frames. Accepts the scanned
    engine's stacked records (the fast path: one host transfer per
    field) or a legacy ``List[FrameRecord]``."""
    if isinstance(records, (list, tuple)):
        records = StackedRecords.from_list(list(records))
    return frameworks_from_stacked(records, tiles_x, tiles_y, n_pixels)


def timed(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds for a jitted callable (blocks on result)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))
