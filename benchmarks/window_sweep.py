"""Fig. 12a reproduction: speedup & PSNR vs warping window size n.

Speedup = (pipeline work of always-full rendering) / (work with TWSR at
window n), where work is the analytic GPU cost the paper's Sec. III
bottleneck analysis uses: preprocess(N) + stage-2 candidates + sort pairs
+ rasterized pairs (+ VTU warp pixels for sparse frames). Wall-clock
ratios are also reported for the jitted CPU pipeline."""
from __future__ import annotations

from typing import List

import jax
import numpy as np

from benchmarks.common import camera, records_to_framework, scenes, trajectory
from repro.core.metrics import psnr
from repro.core.pipeline import RenderConfig, render_full_frame, \
    render_trajectory

WINDOWS = (2, 3, 5, 7, 9)
N_FRAMES = 18


def _work(records, n_pixels) -> float:
    """Scalar GPU-equivalent work (cycles in the simulator's units).

    Vectorized over the stacked (F, ...) record arrays of the scanned
    engine — no per-frame host transfers.
    """
    n_sparse = int((~np.asarray(records.is_full)).sum())
    return (float(np.asarray(records.n_gaussians).sum()) / 2.0
            + float(np.asarray(records.candidate_pairs).sum()) / 32.0
            + float(np.asarray(records.sort_pairs).sum()) / 64.0
            + float(np.asarray(records.raster_pairs).sum())
            + n_sparse * n_pixels / 8.0)


def run() -> List[dict]:
    cam = camera()
    rows = []
    n_pixels = cam.width * cam.height
    for scene_name in ("indoor", "outdoor"):
        scene = scenes()[scene_name]
        poses = trajectory(scene_name, N_FRAMES)
        base_cfg = RenderConfig(window=10 ** 6)
        full_res = render_trajectory(scene, cam, poses,
                                     RenderConfig(window=1))
        work_full = _work(full_res.records, n_pixels)
        full_fn = jax.jit(render_full_frame, static_argnames="cfg")
        refs = [full_fn(scene, cam.with_pose(poses[f]), cfg=base_cfg)[0].rgb
                for f in range(N_FRAMES)]
        for n in WINDOWS:
            cfg = RenderConfig(window=n)
            res = render_trajectory(scene, cam, poses, cfg)
            work_n = _work(res.records, n_pixels)
            quals = [float(psnr(res.frames[f], refs[f]))
                     for f in range(N_FRAMES) if f % n != 0]
            rows.append({
                "bench": "fig12a_window_sweep", "scene": scene_name,
                "window_n": n,
                "speedup_work": round(work_full / work_n, 2),
                "psnr_db": round(float(np.mean(quals)), 2),
            })
    return rows
