"""Fig. 7 reproduction: PSNR under consecutive viewpoint transforms.

Strategies: PW (pixel warping, Potamoi-style: keep every warped pixel,
exact-fill only the holes), TW (tile warping, no mask), TW w/ mask (the
paper's no-cumulative-error mask). One full render, then k consecutive
warps; PSNR vs the per-frame full render."""
from __future__ import annotations

import functools
from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import camera, scenes, trajectory
from repro.core import warp as warp_mod
from repro.core.metrics import psnr, ssim
from repro.core.pipeline import (RenderConfig, render_full_frame,
                                 render_sparse_frame, render_trajectory)

N_FRAMES = 7


def _chain_quality(scene, cam, poses, cfg) -> List[float]:
    res = render_trajectory(scene, cam, poses, cfg)
    full_fn = jax.jit(render_full_frame, static_argnames="cfg")
    out = []
    for f in range(1, poses.shape[0]):
        ref, _, _ = full_fn(scene, cam.with_pose(poses[f]), cfg=cfg)
        out.append(float(psnr(res.frames[f], ref.rgb)))
    return out


def _pw_quality(scene, cam, poses, cfg) -> List[float]:
    """Pixel-warping baseline: chain warps, holes filled from the true
    render (best case for PW), NO tile re-rendering of risky regions."""
    full_fn = jax.jit(render_full_frame, static_argnames="cfg")
    out0, state, _ = full_fn(scene, cam.with_pose(poses[0]), cfg=cfg)
    vals = []
    ref_cam = cam.with_pose(poses[0])
    for f in range(1, poses.shape[0]):
        tgt_cam = cam.with_pose(poses[f])
        ref, _, _ = full_fn(scene, tgt_cam, cfg=cfg)
        w = warp_mod.viewpoint_transform(
            state.rgb, state.exp_depth, state.trunc_depth,
            state.source_mask, ref_cam, tgt_cam)
        rgb = warp_mod.pixel_warp_fill(w, ref.rgb)
        vals.append(float(psnr(rgb, ref.rgb)))
        # chain: PW keeps warped pixels as the next reference
        state = state._replace(
            rgb=rgb,
            exp_depth=jnp.where(w.filled, w.exp_depth, ref.exp_depth),
            trunc_depth=jnp.where(w.filled, w.trunc_depth, ref.trunc_depth),
            source_mask=jnp.ones_like(state.source_mask))
        ref_cam = tgt_cam
    return vals


def run() -> List[dict]:
    cam = camera()
    rows = []
    scene = scenes()["synthetic"]
    poses = trajectory("indoor", N_FRAMES)
    window = 10 ** 6  # never re-key inside the chain
    variants = {
        "tw_mask": RenderConfig(window=window, use_mask=True),
        "tw_nomask": RenderConfig(window=window, use_mask=False),
    }
    for name, cfg in variants.items():
        for k, q in enumerate(_chain_quality(scene, cam, poses, cfg), 1):
            rows.append({"bench": "fig7_warp_quality", "strategy": name,
                         "consecutive_warps": k, "psnr_db": round(q, 2)})
    for k, q in enumerate(_pw_quality(scene, cam, poses,
                                      RenderConfig()), 1):
        rows.append({"bench": "fig7_warp_quality", "strategy": "pw",
                     "consecutive_warps": k, "psnr_db": round(q, 2)})
    return rows
