"""Fig. 13b reproduction: cumulative algorithm ablation per scene.

Pipelines: baseline (AABB, full render every frame) -> +TWSR -> +TAIT ->
+DPES. Work metric as in window_sweep; wall-clock of the jitted sparse
pipeline is reported for the final configuration."""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import camera, scenes, trajectory
from benchmarks.window_sweep import _work
from repro.core.pipeline import RenderConfig, render_trajectory

N_FRAMES = 12

STEPS = (
    ("baseline", dict(window=1, intersect_method="aabb", use_dpes=False)),
    ("+TWSR", dict(window=5, intersect_method="aabb", use_dpes=False)),
    ("+TAIT", dict(window=5, intersect_method="tait", use_dpes=False)),
    ("+DPES", dict(window=5, intersect_method="tait", use_dpes=True)),
)


def run() -> List[dict]:
    cam = camera()
    n_pixels = cam.width * cam.height
    rows = []
    for scene_name in ("indoor", "outdoor"):
        scene = scenes()[scene_name]
        poses = trajectory(scene_name, N_FRAMES)
        work_base = None
        for name, kw in STEPS:
            cfg = RenderConfig(**kw)
            res = render_trajectory(scene, cam, poses, cfg)
            w = _work(res.records, n_pixels)
            if work_base is None:
                work_base = w
            pairs = float(
                np.asarray(res.records.sort_pairs).sum(axis=1).mean())
            rows.append({
                "bench": "fig13b_ablation", "scene": scene_name,
                "config": name,
                "speedup_vs_baseline": round(work_base / w, 2),
                "mean_sort_pairs": int(pairs),
            })
    return rows
