"""Benchmark harness: one module per paper table/figure.

Prints CSV (``key=value`` columns joined by commas) and writes
experiments/artifacts/bench_results.json. ``--only <name>`` selects one;
a selective run MERGES into the artifact (rows of re-run benches are
replaced, every other bench's committed rows survive).
"""
from __future__ import annotations

import argparse
import json
import os
import time

BENCHES = ("intersection", "warp_quality", "window_sweep", "ablation",
           "accelerator", "wallclock", "serve_bench", "cull_ablation")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=BENCHES, default=None)
    args = ap.parse_args()
    selected = (args.only,) if args.only else BENCHES

    all_rows = []
    for name in selected:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        rows = mod.run()
        dt = time.time() - t0
        for r in rows:
            print(",".join(f"{k}={v}" for k, v in r.items()), flush=True)
        print(f"# {name} done in {dt:.1f}s", flush=True)
        all_rows.extend(rows)

    out = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "artifacts", "bench_results.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    if os.path.exists(out):
        with open(out) as f:
            prev = json.load(f)
        fresh = {r["bench"] for r in all_rows}
        all_rows = [r for r in prev if r["bench"] not in fresh] + all_rows
    with open(out, "w") as f:
        json.dump(all_rows, f, indent=1)


if __name__ == "__main__":
    main()
