"""Serve-loop benchmark: continuous batching under Poisson stream churn.

Drives ``repro.serve.StreamServer`` with synthetic traffic — Poisson
arrivals of heterogeneous dolly/orbit trajectories over one shared scene
— and reports the serving metrics the subsystem exists for: per-frame
latency (p50/p99, enqueue -> render-complete, wall clock), rendered
frames/sec, slot utilization of the fixed B-slot batch, and the bucketed
executable cache's compile/hit log (the whole run must stay within one
compilation per R bucket — that is the recompilation bound the
bucketing buys).

Writes ``experiments/artifacts/serve_bench.json`` (full report +
per-round trace) and returns summary rows for ``benchmarks/run.py``.
``--smoke`` is the CI tier-1 configuration: tiny scene, 4 streams over
4 slots, 2 R buckets.
"""
from __future__ import annotations

import argparse
import json
import os
from typing import List

from benchmarks.common import camera, scenes
from repro.core.pipeline import RenderConfig
from repro.serve import (PoissonTraffic, ServeConfig, StreamServer,
                         TrafficConfig)

_ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "artifacts")
ARTIFACT = os.path.join(_ARTIFACTS, "serve_bench.json")
# The CI smoke run writes its own file so a local `--smoke` never
# clobbers the committed full-run artifact.
SMOKE_ARTIFACT = os.path.join(_ARTIFACTS, "serve_bench_smoke.json")

FULL = dict(
    image=64, n_gaussians=3000, window=4, warmup=True,
    scfg=ServeConfig(slots=8, chunk=3, r_buckets=(4, 8, 16), quantile=0.9,
                     adapt_every=2),
    traffic=TrafficConfig(n_streams=12, rate=6.0, min_frames=10,
                          max_frames=16, seed=0),
)
SMOKE = dict(
    image=48, n_gaussians=3000, window=4,
    scfg=ServeConfig(slots=4, chunk=2, r_buckets=(4, 8), quantile=0.9,
                     adapt_every=2),
    scene="indoor",
    traffic=TrafficConfig(n_streams=4, rate=8.0, min_frames=6,
                          max_frames=8, seed=0),
)


def _serve(setup: dict) -> dict:
    cam = camera(setup["image"], setup["image"])
    scene = scenes(setup["n_gaussians"])[setup.get("scene", "outdoor")]
    cfg = RenderConfig(window=setup["window"], capacity=256)
    server = StreamServer(scene, cam, cfg, setup["scfg"])
    if setup.get("warmup"):
        # Compile all bucket executables up front so reported latencies
        # measure serving, not jit cold-start (the smoke config skips
        # this and eats the compiles in-round to stay short).
        server.warmup()
    return server.run(PoissonTraffic(setup["traffic"]), max_rounds=200)


def run(smoke: bool = False) -> List[dict]:
    setup = SMOKE if smoke else FULL
    report = _serve(setup)
    out = SMOKE_ARTIFACT if smoke else ARTIFACT
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(report, f, indent=1)

    n_exec = report["cache"]["distinct_executables"]
    want = min(setup["scfg"].slots, setup["traffic"].n_streams)
    assert report["max_concurrent"] >= want, \
        f"expected {want} concurrent streams at peak, saw " \
        f"{report['max_concurrent']}"
    assert n_exec <= len(setup["scfg"].r_buckets), report["cache"]
    assert report["streams_finished"] == setup["traffic"].n_streams

    return [{
        "bench": "serve", "mode": "smoke" if smoke else "full",
        "streams_served": report["streams_served"],
        "max_concurrent": report["max_concurrent"],
        "frames": report["frames"],
        "latency_p50_ms": report["latency_p50_ms"],
        "latency_p99_ms": report["latency_p99_ms"],
        "frames_per_second": report["frames_per_second"],
        "slot_utilization": report["slot_utilization"],
        "distinct_executables": n_exec,
        "cache_hits": report["cache"]["hits"],
        "warmup_seconds": report["warmup_seconds"],
        "capacity_history": "->".join(map(str,
                                          report["capacity_history"])),
        "num_devices": report["num_devices"],
    }]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI configuration: tiny scene, 4 streams, "
                         "2 buckets")
    args = ap.parse_args()
    for row in run(smoke=args.smoke):
        print(",".join(f"{k}={v}" for k, v in row.items()))
    out = SMOKE_ARTIFACT if args.smoke else ARTIFACT
    print(f"# artifact: {os.path.normpath(out)}")


if __name__ == "__main__":
    main()
