"""Serve-loop benchmark: multi-scene continuous batching under churn.

Drives ``repro.serve.StreamServer`` with synthetic traffic — Poisson
arrivals of heterogeneous dolly/orbit trajectories round-robined over K
registered scenes — and reports the serving metrics the subsystem
exists for: per-frame latency (p50/p99, enqueue -> render-complete,
wall clock), rendered frames/sec, slot utilization of the elastic
B-slot batch, the bucketed executable cache's compile/hit log (the
whole run must stay within one compilation per
``(scene_bucket, B, R)`` key — that is the recompilation bound the
bucketing buys, now across scenes AND batch sizes), and the simulated
ASIC latency of the served frames through the paper's accelerator model
(``core/streaming.py``, recorded-schedule policy) next to the
wall-clock numbers.

Writes ``experiments/artifacts/serve_bench.json`` (full report +
per-round trace) and returns summary rows for ``benchmarks/run.py``.
``--smoke`` is the CI tier-1 configuration: tiny scene, 4 streams over
a (2, 4)-bucketed batch; CI runs it with ``--scenes 3`` so three
same-bucket scenes exercise the shared-executable path end to end.

``--replay {skewed,burst}`` switches to the traffic-replay fairness
comparison (DESIGN.md §11): the same deterministic arrival trace —
10:1 scene-bucket skew, or quiet rounds punctuated by bursts — served
twice, once under the legacy drain-before-switch planner
(``AdmissionConfig(mode="drain")``, the starvation baseline) and once
under mixed rounds with aging. The artifact
(``serve_bench_replay.json``) carries both full reports plus a
before/after comparison block; the skewed run asserts the headline
result: under drain the minority bucket's max wait grows with the
majority backlog, under mixed+aging it stays within
``max_wait_rounds``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
from typing import List, Optional

import jax

from benchmarks.common import camera, scenes
from repro.core.pipeline import RenderConfig
from repro.obs.trace import validate_chrome_trace
from repro.scenes.synthetic import random_blob_scene, structured_scene
from repro.serve import (AdmissionConfig, PoissonTraffic, ReplayTraffic,
                         SceneRegistry, ServeConfig, StreamServer,
                         TrafficConfig, burst_trace, skewed_trace)

_ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "artifacts")
ARTIFACT = os.path.join(_ARTIFACTS, "serve_bench.json")
# The CI smoke run writes its own file so a local `--smoke` never
# clobbers the committed full-run artifact.
SMOKE_ARTIFACT = os.path.join(_ARTIFACTS, "serve_bench_smoke.json")
REPLAY_ARTIFACT = os.path.join(_ARTIFACTS, "serve_bench_replay.json")
REPLAY_SMOKE_ARTIFACT = os.path.join(_ARTIFACTS,
                                     "serve_bench_replay_smoke.json")

FULL = dict(
    image=64, n_gaussians=3000, window=4, warmup=True, scenes=3,
    scfg=ServeConfig(chunk=3, r_buckets=(4, 8, 16), b_buckets=(4, 8),
                     quantile=0.9, adapt_every=2, sim_latency=True),
    traffic=TrafficConfig(n_streams=12, rate=6.0, min_frames=10,
                          max_frames=16, seed=0),
)
SMOKE = dict(
    image=48, n_gaussians=3000, window=4, scenes=1,
    scfg=ServeConfig(chunk=2, r_buckets=(4, 8), b_buckets=(2, 4),
                     quantile=0.9, adapt_every=2, sim_latency=True),
    scene="indoor",
    traffic=TrafficConfig(n_streams=4, rate=8.0, min_frames=6,
                          max_frames=8, seed=0),
)

# The replay comparison serves TWO scenes in DIFFERENT buckets — a
# structured majority scene and a degree-0 blob minority scene — so the
# drain-mode baseline genuinely starves the minority (same-bucket
# scenes would share rounds regardless of planner). ``aging`` is the
# mixed-mode AdmissionConfig under test; ``max_groups_per_round=1`` is
# the worst case for fairness (one bucket per round, so only aging can
# let the minority in).
REPLAY_FULL = dict(
    image=64, n_major=1500, n_minor=400, window=4,
    scfg=ServeConfig(chunk=3, r_buckets=(4, 8, 16), b_buckets=(2, 4, 8),
                     quantile=0.9, adapt_every=2,
                     scene_buckets=(512, 1024, 2048)),
    traffic=TrafficConfig(n_streams=22, min_frames=8, max_frames=12,
                          seed=0),
    skew=10, burst_every=3, burst_size=6,
    aging=AdmissionConfig(max_wait_rounds=2, max_groups_per_round=1),
)
REPLAY_SMOKE = dict(
    image=48, n_major=260, n_minor=90, window=4,
    scfg=ServeConfig(chunk=2, r_buckets=(4, 8), b_buckets=(2, 4),
                     quantile=0.9, adapt_every=2,
                     scene_buckets=(256, 512)),
    traffic=TrafficConfig(n_streams=11, min_frames=6, max_frames=8,
                          seed=0),
    skew=10, burst_every=3, burst_size=4,
    aging=AdmissionConfig(max_wait_rounds=2, max_groups_per_round=1),
)


def _make_scenes(k: int, n: int, first: str) -> List:
    """K distinct same-bucket scenes: the named indoor/outdoor benchmark
    scenes first, then procedural clutter variants. All structured
    (SH degree 1) at one N — same (padded N, sh K) bucket — so they
    MUST share executables (the assertion below). The degree-0 blob
    scene is deliberately excluded: a different sh shape is a different
    bucket, which is bucket-isolation behavior the unit tests cover."""
    named = scenes(n)
    named.pop("synthetic")
    ordered = [named.pop(first)] + list(named.values())
    out = ordered[:k]
    key = jax.random.PRNGKey(1234)
    i = 0
    while len(out) < k:
        out.append(structured_scene(jax.random.fold_in(key, i), n,
                                    clutter=0.3 + 0.1 * (i % 4)))
        i += 1
    return out


def trace_path(name: str) -> str:
    """A bare file name lands next to the JSON artifacts; any path with
    a directory component is used as given."""
    if os.path.dirname(name):
        return name
    return os.path.join(_ARTIFACTS, name)


def _serve(setup: dict, n_scenes: int, scfg: ServeConfig):
    cam = camera(setup["image"], setup["image"])
    registry = SceneRegistry(scfg.scene_buckets)
    for scene in _make_scenes(n_scenes, setup["n_gaussians"],
                              setup.get("scene", "outdoor")):
        registry.register(scene)
    cfg = RenderConfig(window=setup["window"], capacity=256)
    server = StreamServer(registry, cam, cfg, scfg)
    if setup.get("warmup"):
        # Compile all (scene_bucket, B, R) executables up front so
        # reported latencies measure serving, not jit cold-start (the
        # smoke config skips this and eats the compiles in-round to
        # stay short).
        server.warmup()
    traffic = dataclasses.replace(setup["traffic"], scenes=n_scenes)
    return server.run(PoissonTraffic(traffic), max_rounds=200), server


def _write_trace(server: StreamServer, path: str) -> int:
    """Export + validate the run's Chrome trace; assert the observability
    contract CI relies on (DESIGN.md §13): well-formed JSON with round
    spans, and a compile-vs-dispatch split for at least one cache key."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    n_events = server.tracer.write(path)
    summary = validate_chrome_trace(server.tracer.to_chrome())
    for name in ("round", "plan", "dispatch", "barrier", "commit",
                 "compile"):
        assert name in summary["names"], \
            f"trace is missing {name!r} spans: {summary['names']}"
    compiled = [k for k, t in server.cache.stats()["per_key_timing"].items()
                if t["compile_ms"] is not None]
    assert compiled, "no cache key recorded a compile time"
    compile_spans = [ev for ev in server.tracer.events()
                     if ev["name"] == "compile"]
    assert compile_spans and all(
        "key" in ev.get("args", {}) for ev in compile_spans), \
        "compile spans must carry their cache key"
    print(f"# trace: {os.path.normpath(path)} ({n_events} events, "
          f"{summary['tracks']} tracks, {len(compiled)} compiles)")
    return n_events


def run(smoke: bool = False, n_scenes: Optional[int] = None,
        trace: Optional[str] = None) -> List[dict]:
    setup = SMOKE if smoke else FULL
    n_scenes = setup["scenes"] if n_scenes is None else int(n_scenes)
    scfg = setup["scfg"]
    if trace is not None:
        scfg = dataclasses.replace(scfg, trace=True)
    report, server = _serve(setup, n_scenes, scfg)
    if trace is not None:
        _write_trace(server, trace_path(trace))
    out = SMOKE_ARTIFACT if smoke else ARTIFACT
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(report, f, indent=1)

    n_exec = report["cache"]["distinct_executables"]
    max_b = max(scfg.slot_buckets)
    want = min(max_b, setup["traffic"].n_streams)
    assert report["max_concurrent"] >= want, \
        f"expected {want} concurrent streams at peak, saw " \
        f"{report['max_concurrent']}"
    # The recompilation bound: one executable per (scene_bucket, B, R)
    # key, no matter how many scenes / rounds / churn events.
    buckets_in_use = len(report["scenes"]["buckets_in_use"])
    max_keys = len(scfg.slot_buckets) * len(scfg.r_buckets) * buckets_in_use
    assert n_exec <= max_keys, report["cache"]
    # Every stream drains and detaches: no carry was dropped by scene
    # switching or B resizes.
    assert report["streams_finished"] == setup["traffic"].n_streams
    if n_scenes > 1:
        # Same-bucket scene reuse: more distinct scenes served than
        # compiled executables can only mean scenes shared executables
        # (the hit/miss log records every reuse).
        served_scenes = set()
        for r in report["rounds_trace"]:
            served_scenes.update(r.get("scene_ids", []))
        assert len(served_scenes) >= min(n_scenes,
                                         setup["traffic"].n_streams), \
            f"only scenes {served_scenes} were served"
        assert report["cache"]["hits"] > 0, report["cache"]
    if scfg.b_buckets is not None and len(scfg.b_buckets) > 1:
        # Elastic B: the run must contain at least one resize event
        # (served without dropping carries, per the assert above).
        assert len(set(report["slots_history"])) >= 2, \
            report["slots_history"]
    assert report["sim"] is not None and report["sim"]["frames"] > 0

    return [{
        "bench": "serve", "mode": "smoke" if smoke else "full",
        "scenes": n_scenes,
        "streams_served": report["streams_served"],
        "max_concurrent": report["max_concurrent"],
        "frames": report["frames"],
        "latency_p50_ms": report["latency_p50_ms"],
        "latency_p99_ms": report["latency_p99_ms"],
        "frames_per_second": report["frames_per_second"],
        "slot_utilization": report["slot_utilization"],
        "distinct_executables": n_exec,
        "cache_hits": report["cache"]["hits"],
        "warmup_seconds": report["warmup_seconds"],
        "capacity_history": "->".join(map(str,
                                          report["capacity_history"])),
        "slots_history": "->".join(map(str, report["slots_history"])),
        "sim_cycles_per_frame": report["sim"]["cycles_per_frame"],
        "sim_latency_p50_cycles": report["sim"]["latency_p50_cycles"],
        "sim_latency_p99_cycles": report["sim"]["latency_p99_cycles"],
        "jain_service": report["fairness"]["jain_service"],
        "max_wait_rounds": report["fairness"]["max_wait_rounds"],
        "deferred": report["fairness"]["deferred"],
        "num_devices": report["num_devices"],
    }]


def _replay_serve(setup: dict, pattern: str,
                  admission: AdmissionConfig) -> dict:
    """One leg of the before/after comparison: the deterministic trace
    (scene index 0 = majority bucket, 1 = minority bucket) served under
    ``admission``. Fresh server + traffic per leg, identical seeds —
    the ONLY difference between legs is the round planner."""
    cam = camera(setup["image"], setup["image"])
    registry = SceneRegistry(setup["scfg"].scene_buckets)
    registry.register(structured_scene(jax.random.PRNGKey(21),
                                       setup["n_major"], clutter=0.4))
    registry.register(random_blob_scene(jax.random.PRNGKey(22),
                                        setup["n_minor"]))
    cfg = RenderConfig(window=setup["window"], capacity=256)
    scfg = dataclasses.replace(setup["scfg"], admission=admission)
    server = StreamServer(registry, cam, cfg, scfg)
    n = setup["traffic"].n_streams
    if pattern == "skewed":
        trace = skewed_trace(n, skew=setup["skew"])
    else:
        trace = burst_trace(n, burst_every=setup["burst_every"],
                            burst_size=setup["burst_size"], scenes=2)
    return server.run(ReplayTraffic(trace, setup["traffic"]),
                      max_rounds=400)


def run_replay(smoke: bool = False, pattern: str = "skewed") -> List[dict]:
    """The starvation before/after: drain-mode baseline vs mixed rounds
    with aging, same trace. Writes ``serve_bench_replay.json`` and
    asserts the fix's contract (see module docstring)."""
    if pattern not in ("skewed", "burst"):
        raise ValueError(f"pattern must be 'skewed' or 'burst', "
                         f"got {pattern!r}")
    setup = REPLAY_SMOKE if smoke else REPLAY_FULL
    aging = setup["aging"]
    before = _replay_serve(setup, pattern, AdmissionConfig(mode="drain"))
    after = _replay_serve(setup, pattern, aging)

    minority = str(tuple(after["scenes"]["per_scene"]["1"]["bucket"]))
    rows = []
    for leg, report in (("drain", before), ("mixed", after)):
        mb = report["per_bucket"].get(minority, {})
        rows.append({
            "bench": "serve_replay", "pattern": pattern, "planner": leg,
            "mode": "smoke" if smoke else "full",
            "streams_finished": report["streams_finished"],
            "frames": report["frames"],
            "rounds": report["rounds"],
            "jain_service": report["fairness"]["jain_service"],
            "max_wait_rounds": report["fairness"]["max_wait_rounds"],
            "deferred": report["fairness"]["deferred"],
            "minority_frames": mb.get("frames", 0),
            "minority_max_wait": mb.get("max_wait_rounds", 0),
            "minority_share": mb.get("share"),
            "minority_p99_ms": mb.get("latency_p99_ms"),
            "latency_p99_ms": report["latency_p99_ms"],
        })
    comparison = {
        "pattern": pattern, "minority_bucket": minority,
        "max_wait_bound": aging.max_wait_rounds,
        "minority_max_wait_before": rows[0]["minority_max_wait"],
        "minority_max_wait_after": rows[1]["minority_max_wait"],
        "jain_before": rows[0]["jain_service"],
        "jain_after": rows[1]["jain_service"],
        "minority_p99_ms_before": rows[0]["minority_p99_ms"],
        "minority_p99_ms_after": rows[1]["minority_p99_ms"],
    }
    out = REPLAY_SMOKE_ARTIFACT if smoke else REPLAY_ARTIFACT
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump({"comparison": comparison, "before": before,
                   "after": after}, f, indent=1)

    n = setup["traffic"].n_streams
    scfg = setup["scfg"]
    for report in (before, after):
        # both planners eventually serve everyone (drain starves, it
        # does not drop) and stay within the compile bound
        assert report["streams_finished"] == n, report["streams_finished"]
        buckets_in_use = len(report["scenes"]["buckets_in_use"])
        max_keys = len(scfg.slot_buckets) * len(scfg.r_buckets) \
            * buckets_in_use
        assert report["cache"]["distinct_executables"] <= max_keys
    # the headline: minority service is nonzero and its wait is bounded
    # by max_wait_rounds under mixed+aging
    assert rows[1]["minority_frames"] > 0, rows[1]
    assert rows[1]["minority_max_wait"] <= aging.max_wait_rounds, rows[1]
    if pattern == "skewed":
        # ... while the drain baseline demonstrably starved it
        assert rows[0]["minority_max_wait"] > aging.max_wait_rounds, \
            rows[0]
        assert rows[1]["jain_service"] >= rows[0]["jain_service"], \
            (rows[0], rows[1])
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI configuration: tiny scene, 4 streams, "
                         "2 buckets per axis")
    ap.add_argument("--scenes", type=int, default=None,
                    help="serve this many scenes round-robin (default: "
                         "the mode's preset; full preset is 3)")
    ap.add_argument("--replay", choices=("skewed", "burst"), default=None,
                    help="run the starvation before/after comparison on "
                         "this arrival pattern instead of Poisson traffic")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="record serve-round spans and write a Chrome-"
                         "trace JSON (loads in ui.perfetto.dev); a bare "
                         "file name lands in experiments/artifacts/")
    args = ap.parse_args()
    if args.replay:
        if args.trace:
            ap.error("--trace applies to the Poisson run, not --replay")
        rows = run_replay(smoke=args.smoke, pattern=args.replay)
        out = REPLAY_SMOKE_ARTIFACT if args.smoke else REPLAY_ARTIFACT
    else:
        rows = run(smoke=args.smoke, n_scenes=args.scenes,
                   trace=args.trace)
        out = SMOKE_ARTIFACT if args.smoke else ARTIFACT
    for row in rows:
        print(",".join(f"{k}={v}" for k, v in row.items()))
    print(f"# artifact: {os.path.normpath(out)}")


if __name__ == "__main__":
    main()
