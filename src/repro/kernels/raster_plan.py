"""Fused Pallas TPU kernel: per-slot depth sort + raster in one pass.

This is the plan-slot production kernel (DESIGN.md §9): one grid step per
TilePlan slot; the slot's K compacted Gaussians are loaded into VMEM
once, depth-sorted by the GSU bitonic network (the same network as
tile_sort.py, but the full attribute record rides the compare-exchanges
as the payload), and immediately alpha-blended by the VRU chunk loop
(raster_tile.py's math) — keys and values never leave VMEM between the
sort and the raster, which is the paper's no-HBM-roundtrip streaming
contract.

Input contract (the (R, K) VMEM layout, see DESIGN.md §9):
  - each slot's ``count`` real pairs occupy lanes ``[0, count)`` in ANY
    depth order; lanes past ``count`` are padding (ignored — the sort
    keys them +inf and the blend masks their opacity to 0);
  - ``slot_active`` False implies ``count == 0`` on the plan path
    (pipeline masks intersections by ``plan.slot_active`` before
    binning); the kernel enforces the conjunction either way.

Masked / empty slots cost ~nothing: the bitonic network is gated behind
a ``lax.cond`` on ``slot_active & (count > 0)`` and the blend
``while_loop`` runs zero chunks, so a sparse plan's padded slots write
their empty outputs (rgb 0, T = 1) and move on.

VMEM footprint per slot at K=1024: 11 attr lanes (10 attributes + the
original lane index riding the sort for the contribution unscramble)
* 4B * K = 44 KiB resident, plus the (256 pixels x G-chunk) blend
intermediates — same budget as raster_tile.py, the sort works in-place
on the resident lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.camera import TILE
from repro.kernels.raster_tile import ALPHA_MAX, ALPHA_MIN, T_EPS


def _fused_kernel(mean_ref, conic_ref, rgb_ref, opac_ref, depth_ref,
                  origin_ref, count_ref, active_ref,
                  rgb_out, trans_out, depth_out, tdepth_out, processed_out,
                  contrib_out, srclane_out, *, k: int, chunk: int, tile: int):
    p = tile * tile
    count = count_ref[0]
    active = (active_ref[0] > 0) & (count > 0)
    lane = jax.lax.broadcasted_iota(jnp.int32, (k, 1), 0)[:, 0]
    in_count = lane < count

    # ---- GSU: bitonic depth sort over the slot's K lanes (in VMEM) ----
    # Padding lanes get +inf keys so they sink to the end; after the sort
    # the slot's `count` real pairs occupy lanes [0, count) ascending in
    # depth, exactly what the front-to-back blend below assumes.
    #
    # The network is expressed as reshape-paired compare-exchanges (lanes
    # i and i^stride meet as the two halves of a (k/2s, 2, s) view) and the
    # full attribute record rides the swaps as the sort payload — no lane
    # gathers anywhere, neither in the network nor after it. Gather chains
    # are what make the standalone tile_sort kernel compile in
    # minutes-to-hours under interpret mode on CPU (tests/test_kernels_sort
    # tiers); swap-through payloads keep the fused kernel's whole graph
    # elementwise + reshape, which XLA compiles fast, and match how the
    # hardware GSU streams key+record pairs through its network anyway.
    keys0 = jnp.where(in_count, depth_ref[0, :], jnp.inf)
    # The last payload element is the lane's ORIGINAL index (f32, exact
    # for any VMEM-sized K): it rides the compare-exchanges like every
    # other attribute, so after the sort it is the permutation the wrapper
    # needs to report per-lane blend contributions in input lane order —
    # still no gathers inside the kernel.
    payload0 = (
        jnp.where(in_count, opac_ref[0, :], 0.0),
        mean_ref[0, :, 0], mean_ref[0, :, 1],
        conic_ref[0, :, 0], conic_ref[0, :, 1], conic_ref[0, :, 2],
        rgb_ref[0, :, 0], rgb_ref[0, :, 1], rgb_ref[0, :, 2],
        lane.astype(jnp.float32),
    )

    def do_sort(kp):
        keys, payload = kp

        def exchange(arrs, swap, stride):
            out = []
            for a in arrs:
                a2 = a.reshape(-1, 2, stride)
                lo = jnp.where(swap, a2[:, 1], a2[:, 0])
                hi = jnp.where(swap, a2[:, 0], a2[:, 1])
                out.append(jnp.stack([lo, hi], axis=1).reshape(k))
            return out

        span = 2
        while span <= k:
            stride = span // 2
            while stride >= 1:
                k2 = keys.reshape(-1, 2, stride)
                lo_k, hi_k = k2[:, 0], k2[:, 1]
                # Low lane index of each pair is b*2*stride + j (j <
                # stride < span), so bit log2(span) — the ascending /
                # descending flag — is carried entirely by the pair-block
                # index b.
                b = jax.lax.broadcasted_iota(
                    jnp.int32, (k // (2 * stride), 1), 0)
                up = ((b * (2 * stride)) & span) == 0
                swap = jnp.where(up, lo_k > hi_k, lo_k < hi_k)
                keys, *payload = exchange([keys, *payload], swap, stride)
                stride //= 2
            span *= 2
        return keys, tuple(payload)

    # Masked slots skip the whole network (the blend below runs 0 chunks
    # regardless, because used_chunks is gated on `active`).
    keys, payload = jax.lax.cond(active, do_sort, lambda kp: kp,
                                 (keys0, payload0))
    op, mx, my, ca, cb, cc, cr, cg, cbl, src = payload
    # Sorted depth comes free from the sort keys; padding -> 0 (not inf):
    # it blends with w=0 and 0 * inf would NaN the depth accumulators.
    dep = jnp.where(in_count, keys, 0.0)

    # ---- VRU: chunked front-to-back blend (raster_tile.py math) ----
    ox = origin_ref[0, 0]
    oy = origin_ref[0, 1]
    iy = jax.lax.broadcasted_iota(jnp.float32, (tile, tile), 0)
    ix = jax.lax.broadcasted_iota(jnp.float32, (tile, tile), 1)
    px = (ix + ox + 0.5).reshape(p)
    py = (iy + oy + 0.5).reshape(p)

    n_chunks = k // chunk
    used_chunks = jnp.where(
        active, jnp.minimum((count + chunk - 1) // chunk, n_chunks), 0)

    def sl(a, i):
        return jax.lax.dynamic_slice_in_dim(a, i * chunk, chunk)

    def chunk_body(state):
        i, c_acc, t_run, done, d_acc, w_acc, td_max, contrib = state
        mxs, mys = sl(mx, i), sl(my, i)
        cas, cbs, ccs = sl(ca, i), sl(cb, i), sl(cc, i)
        col = jnp.stack([sl(cr, i), sl(cg, i), sl(cbl, i)], axis=1)  # (G, 3)
        ops_ = sl(op, i)
        deps = sl(dep, i)

        dx = px[:, None] - mxs[None, :]             # (P, G)
        dy = py[:, None] - mys[None, :]
        power = (-0.5 * (cas[None, :] * dx * dx + ccs[None, :] * dy * dy)
                 - cbs[None, :] * dx * dy)
        alpha = jnp.minimum(ops_[None, :] * jnp.exp(power), ALPHA_MAX)
        alpha = jnp.where(alpha >= ALPHA_MIN, alpha, 0.0)

        factors = 1.0 - alpha
        cp = jnp.cumprod(factors, axis=1)           # inclusive prefix (P, G)
        tp = t_run[:, None] * cp                    # T after blending j
        t_before = t_run[:, None] * jnp.concatenate(
            [jnp.ones_like(cp[:, :1]), cp[:, :-1]], axis=1)
        # Sticky done across chunks, exactly raster_tile.py's semantics.
        blend = (tp >= T_EPS) & (~done[:, None])
        w = jnp.where(blend, alpha * t_before, 0.0)  # (P, G)

        c_acc = c_acc + w @ col                     # (P, 3) MXU
        d_acc = d_acc + jnp.sum(w * deps[None, :], axis=1)
        w_acc = w_acc + jnp.sum(w, axis=1)
        td_max = jnp.maximum(
            td_max, jnp.max(jnp.where(blend & (alpha > 0.0), deps[None, :],
                                      0.0), axis=1))
        t_run = jnp.min(jnp.where(blend, tp, t_run[:, None]), axis=1)
        done = done | (tp[:, -1] < T_EPS)
        # Per-SORTED-lane contribution — a chunk-slice update (no
        # scatter); the wrapper inverts the sort permutation outside the
        # kernel to report it in input lane order.
        contrib = jax.lax.dynamic_update_slice_in_dim(
            contrib, jnp.sum(w, axis=0), i * chunk, axis=0)
        return i + 1, c_acc, t_run, done, d_acc, w_acc, td_max, contrib

    def chunk_cond(state):
        i, _, _, done, _, _, _, _ = state
        return (i < used_chunks) & jnp.any(~done)

    init = (jnp.int32(0),
            jnp.zeros((p, 3), jnp.float32),
            jnp.ones((p,), jnp.float32),
            jnp.zeros((p,), bool),
            jnp.zeros((p,), jnp.float32),
            jnp.zeros((p,), jnp.float32),
            jnp.zeros((p,), jnp.float32),
            jnp.zeros((k,), jnp.float32))
    (n_done, c_acc, t_run, done, d_acc, w_acc, td_max,
     contrib) = jax.lax.while_loop(chunk_cond, chunk_body, init)

    rgb_out[0] = c_acc.reshape(tile, tile, 3)
    trans_out[0] = t_run.reshape(tile, tile)
    depth_out[0] = (d_acc / jnp.maximum(w_acc, 1e-8)).reshape(tile, tile)
    tdepth_out[0] = td_max.reshape(tile, tile)
    processed_out[0] = jnp.minimum(n_done * chunk, count)
    contrib_out[0] = contrib
    srclane_out[0] = src


def _pow2_at_least(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def raster_plan_fused(mean2d, conic, rgb, opacity, depth, origins, counts,
                      slot_active=None, *, chunk: int = 64, tile: int = TILE,
                      interpret: bool = True):
    """Fused sort+raster over plan slots. Inputs (R, K, ...) compacted bins.

    Per-slot lanes need NOT be depth-sorted — the kernel sorts (that is
    the point); they must be packed (real pairs first, see module
    docstring). ``slot_active`` (R,) bool gates whole slots (default:
    ``counts > 0``). K is padded to a power of two internally; ``chunk``
    must be a power of two (so it divides the padded K).

    Returns rgb (R, tile, tile, 3), trans, exp_depth, trunc_depth (each
    (R, tile, tile)), processed (R,) int32, lane_contrib (R, K) float32.
    The contribution is reported in INPUT lane order even though the
    kernel blends in sorted order: the original lane index rides the sort
    as one more payload attribute and the inverse permutation is applied
    by scatter out here, so the kernel itself stays gather/scatter-free.
    Masked slots skip the sort (identity permutation) and report zeros.
    """
    r, k = opacity.shape
    if chunk & (chunk - 1):
        raise ValueError(f"chunk={chunk} must be a power of two")
    if slot_active is None:
        slot_active = counts > 0

    k_pad = _pow2_at_least(max(k, chunk))
    if k_pad != k:
        pad = ((0, 0), (0, k_pad - k))
        mean2d = jnp.pad(mean2d, pad + ((0, 0),))
        conic = jnp.pad(conic, pad + ((0, 0),))
        rgb = jnp.pad(rgb, pad + ((0, 0),))
        opacity = jnp.pad(opacity, pad)
        depth = jnp.pad(depth, pad)

    kernel = functools.partial(_fused_kernel, k=k_pad, chunk=chunk, tile=tile)
    f32 = jnp.float32
    out_shapes = (
        jax.ShapeDtypeStruct((r, tile, tile, 3), f32),
        jax.ShapeDtypeStruct((r, tile, tile), f32),
        jax.ShapeDtypeStruct((r, tile, tile), f32),
        jax.ShapeDtypeStruct((r, tile, tile), f32),
        jax.ShapeDtypeStruct((r,), jnp.int32),
        jax.ShapeDtypeStruct((r, k_pad), f32),
        jax.ShapeDtypeStruct((r, k_pad), f32),
    )
    in_specs = [
        pl.BlockSpec((1, k_pad, 2), lambda i: (i, 0, 0)),
        pl.BlockSpec((1, k_pad, 3), lambda i: (i, 0, 0)),
        pl.BlockSpec((1, k_pad, 3), lambda i: (i, 0, 0)),
        pl.BlockSpec((1, k_pad), lambda i: (i, 0)),
        pl.BlockSpec((1, k_pad), lambda i: (i, 0)),
        pl.BlockSpec((1, 2), lambda i: (i, 0)),
        pl.BlockSpec((1,), lambda i: (i,)),
        pl.BlockSpec((1,), lambda i: (i,)),
    ]
    out_specs = (
        pl.BlockSpec((1, tile, tile, 3), lambda i: (i, 0, 0, 0)),
        pl.BlockSpec((1, tile, tile), lambda i: (i, 0, 0)),
        pl.BlockSpec((1, tile, tile), lambda i: (i, 0, 0)),
        pl.BlockSpec((1, tile, tile), lambda i: (i, 0, 0)),
        pl.BlockSpec((1,), lambda i: (i,)),
        pl.BlockSpec((1, k_pad), lambda i: (i, 0)),
        pl.BlockSpec((1, k_pad), lambda i: (i, 0)),
    )
    (rgb_o, trans_o, depth_o, tdepth_o, processed_o, contrib_sorted,
     srclane) = pl.pallas_call(
        kernel, grid=(r,), in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shapes, interpret=interpret,
    )(mean2d.astype(f32), conic.astype(f32), rgb.astype(f32),
      opacity.astype(f32), depth.astype(f32), origins.astype(f32),
      counts.astype(jnp.int32), slot_active.astype(jnp.int32))
    # Undo the in-kernel sort: srclane is each sorted lane's original
    # index, a true permutation of [0, k_pad) per slot (padding lanes
    # included), so one scatter recovers input-lane order exactly.
    src = srclane.astype(jnp.int32)
    rows = jnp.arange(r, dtype=jnp.int32)[:, None]
    contrib = jnp.zeros((r, k_pad), f32).at[rows, src].set(contrib_sorted)
    return rgb_o, trans_o, depth_o, tdepth_o, processed_o, contrib[:, :k]
