"""Pallas TPU kernel for the fused preprocess stage (the paper's CCU).

LS-Gaussian's CCU replaces GSCore's dual OBB-intersection units with one
sqrt + log operator (paper Sec. V-A / VI-A); this kernel is the TPU
realization: a single fused pass per Gaussian computing camera transform,
EWA projection, conic, eigen-decomposition, the classic 3-sigma radius and
TAIT's opacity-aware radii + tight bbox (eqs. 4 and 6).

Blocked over N (BLOCK_N Gaussians per grid step); the camera is a tiny
(4,4) + (8,) operand replicated to every block. Pure VPU math — one exp/log
and two sqrt per Gaussian, exactly the operator budget the paper's CCU adds.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ALPHA_THRESHOLD = 1.0 / 255.0
BLOCK_N = 256


def _preproc_kernel(means_ref, scales_ref, quats_ref, opac_ref,
                    w2c_ref, intrin_ref,
                    mean2d_out, conic_out, depth_out, aux_out, minor_out,
                    *, dilation: float, near: float, frustum_margin: float):
    means = means_ref[...]                     # (B, 3)
    log_scales = scales_ref[...]               # (B, 3)
    quats = quats_ref[...]                     # (B, 4)
    opac = opac_ref[...]                       # (B,)
    w2c = w2c_ref[...]                         # (4, 4)
    fx, fy, cx, cy = (intrin_ref[0], intrin_ref[1], intrin_ref[2],
                      intrin_ref[3])
    width, height = intrin_ref[4], intrin_ref[5]

    rot = w2c[:3, :3]
    t = w2c[:3, 3]
    p_cam = means @ rot.T + t                  # (B, 3)
    z = p_cam[:, 2]
    safe_z = jnp.maximum(z, near)
    u = fx * p_cam[:, 0] / safe_z + cx
    v = fy * p_cam[:, 1] / safe_z + cy

    # Quaternion -> rotation, R S: world covariance = (RS)(RS)^T.
    qn = quats / jnp.sqrt(jnp.sum(quats * quats, axis=1, keepdims=True) + 1e-12)
    qw, qx, qy, qz = qn[:, 0], qn[:, 1], qn[:, 2], qn[:, 3]
    s = jnp.exp(log_scales)                    # (B, 3)
    r00 = 1 - 2 * (qy * qy + qz * qz)
    r01 = 2 * (qx * qy - qw * qz)
    r02 = 2 * (qx * qz + qw * qy)
    r10 = 2 * (qx * qy + qw * qz)
    r11 = 1 - 2 * (qx * qx + qz * qz)
    r12 = 2 * (qy * qz - qw * qx)
    r20 = 2 * (qx * qz - qw * qy)
    r21 = 2 * (qy * qz + qw * qx)
    r22 = 1 - 2 * (qx * qx + qy * qy)
    # M = R_g diag(s): rows of world-rotation scaled by s columns.
    m_rows = [
        jnp.stack([r00 * s[:, 0], r01 * s[:, 1], r02 * s[:, 2]], -1),
        jnp.stack([r10 * s[:, 0], r11 * s[:, 1], r12 * s[:, 2]], -1),
        jnp.stack([r20 * s[:, 0], r21 * s[:, 1], r22 * s[:, 2]], -1),
    ]
    m3 = jnp.stack(m_rows, 1)                  # (B, 3, 3)
    cov3d = m3 @ jnp.swapaxes(m3, 1, 2)        # (B, 3, 3)

    lim_x = frustum_margin * width / (2.0 * fx)
    lim_y = frustum_margin * height / (2.0 * fy)
    tx = jnp.clip(p_cam[:, 0] / safe_z, -lim_x, lim_x) * safe_z
    ty = jnp.clip(p_cam[:, 1] / safe_z, -lim_y, lim_y) * safe_z
    inv_z = 1.0 / safe_z
    inv_z2 = inv_z * inv_z
    zero = jnp.zeros_like(inv_z)
    j0 = jnp.stack([fx * inv_z, zero, -fx * tx * inv_z2], -1)   # (B, 3)
    j1 = jnp.stack([zero, fy * inv_z, -fy * ty * inv_z2], -1)
    jm = jnp.stack([j0, j1], 1)                # (B, 2, 3)
    mw = jm @ rot[None]                        # (B, 2, 3)
    cov2d = mw @ cov3d @ jnp.swapaxes(mw, 1, 2)  # (B, 2, 2)
    a = cov2d[:, 0, 0] + dilation
    b = cov2d[:, 0, 1]
    c = cov2d[:, 1, 1] + dilation

    det = a * c - b * b
    det_safe = jnp.maximum(det, 1e-12)
    con_a = c / det_safe
    con_b = -b / det_safe
    con_c = a / det_safe

    mid = 0.5 * (a + c)
    half_diff = 0.5 * (a - c)
    disc = jnp.sqrt(jnp.maximum(half_diff * half_diff + b * b, 1e-12))
    lam1 = mid + disc
    lam2 = jnp.maximum(mid - disc, 1e-8)
    ex = jnp.where(jnp.abs(b) > 1e-12, b, jnp.where(a <= c, 1.0, 0.0))
    ey = jnp.where(jnp.abs(b) > 1e-12, lam2 - a, jnp.where(a <= c, 0.0, 1.0))
    en = jnp.sqrt(ex * ex + ey * ey) + 1e-12

    radius3 = jnp.ceil(3.0 * jnp.sqrt(lam1))
    log_ratio = jnp.log(jnp.maximum(opac / ALPHA_THRESHOLD, 1.0 + 1e-6))
    r_major = jnp.sqrt(2.0 * log_ratio * lam1)
    r_minor = jnp.sqrt(2.0 * log_ratio * lam2)
    half_w = jnp.sqrt(jnp.maximum(a / lam1, 0.0)) * r_major
    half_h = jnp.sqrt(jnp.maximum(c / lam1, 0.0)) * r_major

    in_front = z > near
    visible = opac > ALPHA_THRESHOLD
    on_screen = ((u + radius3 > 0) & (u - radius3 < width)
                 & (v + radius3 > 0) & (v - radius3 < height))
    valid = in_front & visible & on_screen & (det > 1e-12)

    mean2d_out[...] = jnp.stack([u, v], -1)
    conic_out[...] = jnp.stack([con_a, con_b, con_c], -1)
    depth_out[...] = z
    aux_out[...] = jnp.stack([radius3, r_major, r_minor, half_w, half_h,
                              valid.astype(jnp.float32)], -1)
    minor_out[...] = jnp.stack([ex / en, ey / en], -1)


def preprocess_geom_pallas(means, log_scales, quats, opacity, w2c, intrin,
                           *, dilation: float = 0.3, near: float = 0.05,
                           frustum_margin: float = 1.3,
                           block_n: int = BLOCK_N, interpret: bool = True):
    """Fused preprocess over N Gaussians (padded to block_n).

    Returns mean2d (N,2), conic (N,3), depth (N,), aux (N,6), minor (N,2)
    with aux = [radius3, r_major, r_minor, half_w, half_h, valid].
    """
    n = means.shape[0]
    n_pad = (n + block_n - 1) // block_n * block_n
    pad = n_pad - n

    def padn(x):
        cfg = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
        return jnp.pad(x, cfg)

    f32 = jnp.float32
    means_p = padn(means.astype(f32))
    scales_p = padn(log_scales.astype(f32))
    quats_p = padn(quats.astype(f32)).at[n:, 0].set(1.0) if pad else padn(quats.astype(f32))
    opac_p = padn(opacity.astype(f32))

    kernel = functools.partial(_preproc_kernel, dilation=dilation, near=near,
                               frustum_margin=frustum_margin)
    grid = (n_pad // block_n,)
    out_shapes = (
        jax.ShapeDtypeStruct((n_pad, 2), f32),
        jax.ShapeDtypeStruct((n_pad, 3), f32),
        jax.ShapeDtypeStruct((n_pad,), f32),
        jax.ShapeDtypeStruct((n_pad, 6), f32),
        jax.ShapeDtypeStruct((n_pad, 2), f32),
    )
    in_specs = [
        pl.BlockSpec((block_n, 3), lambda i: (i, 0)),
        pl.BlockSpec((block_n, 3), lambda i: (i, 0)),
        pl.BlockSpec((block_n, 4), lambda i: (i, 0)),
        pl.BlockSpec((block_n,), lambda i: (i,)),
        pl.BlockSpec((4, 4), lambda i: (0, 0)),   # camera: replicated
        pl.BlockSpec((6,), lambda i: (0,)),
    ]
    out_specs = (
        pl.BlockSpec((block_n, 2), lambda i: (i, 0)),
        pl.BlockSpec((block_n, 3), lambda i: (i, 0)),
        pl.BlockSpec((block_n,), lambda i: (i,)),
        pl.BlockSpec((block_n, 6), lambda i: (i, 0)),
        pl.BlockSpec((block_n, 2), lambda i: (i, 0)),
    )
    outs = pl.pallas_call(
        kernel, grid=grid, in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shapes, interpret=interpret,
    )(means_p, scales_p, quats_p, opac_p,
      jnp.asarray(w2c, f32), jnp.asarray(intrin, f32))
    return tuple(o[:n] for o in outs)
