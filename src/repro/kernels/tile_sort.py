"""Pallas TPU kernel for the per-tile depth sorter (the paper's GSU).

Bitonic sorting network over each tile's K depth keys (with payload
indices), one grid step per tile. K is padded to a power of two; +inf
padding keys sink to the end, matching binning.py semantics. The network
is data-independent — log2(K)·(log2(K)+1)/2 compare-exchange sweeps, each
a vectorized gather + select over the (K,) lane dimension, which is how a
streaming hardware sorter (GSCore's GSU) maps onto the VPU.

Used as the in-kernel alternative to the XLA `top_k` path in binning.py;
both are validated against `kernels/ref.py::tile_sort_ref`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bitonic_kernel(keys_ref, vals_ref, keys_out, vals_out, *, k: int):
    keys = keys_ref[0, :]
    vals = vals_ref[0, :]
    idx = jax.lax.broadcasted_iota(jnp.int32, (k, 1), 0)[:, 0]

    span = 2
    while span <= k:
        stride = span // 2
        while stride >= 1:
            partner = idx ^ stride
            pk = keys[partner]
            pv = vals[partner]
            # ascending iff the span-block index is even
            up = (idx & span) == 0
            is_low = partner > idx
            swap = jnp.where(is_low, keys > pk, keys < pk)
            swap = jnp.where(up, swap, ~swap)
            keys = jnp.where(swap, pk, keys)
            vals = jnp.where(swap, pv, vals)
            stride //= 2
        span *= 2

    keys_out[0, :] = keys
    vals_out[0, :] = vals


def tile_sort_pallas(keys: jax.Array, values: jax.Array, *,
                     interpret: bool = True):
    """Sort each row ascending. keys (T, K) f32, values (T, K) i32.

    K is padded to the next power of two with +inf keys (dropped on
    return)."""
    t, k = keys.shape
    k_pad = 1
    while k_pad < k:
        k_pad *= 2
    if k_pad != k:
        keys = jnp.pad(keys, ((0, 0), (0, k_pad - k)),
                       constant_values=jnp.inf)
        values = jnp.pad(values, ((0, 0), (0, k_pad - k)),
                         constant_values=-1)

    kernel = functools.partial(_bitonic_kernel, k=k_pad)
    out_k, out_v = pl.pallas_call(
        kernel,
        grid=(t,),
        in_specs=[pl.BlockSpec((1, k_pad), lambda i: (i, 0)),
                  pl.BlockSpec((1, k_pad), lambda i: (i, 0))],
        out_specs=(pl.BlockSpec((1, k_pad), lambda i: (i, 0)),
                   pl.BlockSpec((1, k_pad), lambda i: (i, 0))),
        out_shape=(jax.ShapeDtypeStruct((t, k_pad), jnp.float32),
                   jax.ShapeDtypeStruct((t, k_pad), jnp.int32)),
        interpret=interpret,
    )(keys.astype(jnp.float32), values.astype(jnp.int32))
    return out_k[:, :k], out_v[:, :k]
