"""Pallas TPU kernels for the perf-critical 3DGS stages (VRU / CCU / GSU)."""
