"""Jit'd public wrappers around the Pallas kernels.

Every op takes ``impl`` selecting between (DESIGN.md §9):
  - "pallas_fused": the fused per-slot sort+raster Pallas kernel
                    (kernels/raster_plan.py) — the default device path on
                    TPU backends (see ``default_impl``)
  - "pallas"      : the raster-only Pallas kernel over pre-sorted bins
                    (interpret=True on CPU, compiled on TPU)
  - "jnp_chunked" : vectorized pure-jnp path with identical chunked math —
                    the fast CPU execution path used by benchmarks
  - "ref"         : the sequential oracle (kernels/ref.py)
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.camera import TILE
from repro.kernels import ref as ref_kernels
from repro.kernels.raster_tile import (ALPHA_MAX, ALPHA_MIN, T_EPS,
                                       raster_tiles_pallas)
from repro.kernels.raster_plan import raster_plan_fused
from repro.kernels.preprocess import preprocess_geom_pallas
from repro.obs.trace import annotate


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# Valid ``impl`` names for raster_tiles, in preference order — the single
# source of truth example CLIs build their --impl choices from.
RASTER_IMPLS = ("pallas_fused", "pallas", "jnp_chunked", "ref")


def default_impl() -> str:
    """The raster ``impl`` for this backend: the fused plan-slot kernel on
    TPU, the vectorized jnp path everywhere else (interpret-mode Pallas is
    a correctness tool, not an execution path — DESIGN.md §9)."""
    return "pallas_fused" if _on_tpu() else "jnp_chunked"


def _raster_tile_chunked_jnp(mean2d, conic, rgb, opacity, depth, origin,
                             count, *, chunk: int, tile: int):
    """One tile, chunked math identical to the Pallas kernel, pure jnp."""
    k = opacity.shape[0]
    ii = jnp.arange(tile, dtype=jnp.float32)
    py_g, px_g = jnp.meshgrid(ii, ii, indexing="ij")
    px = px_g.ravel() + origin[0] + 0.5
    py = py_g.ravel() + origin[1] + 0.5
    p = tile * tile

    def body(carry, sl):
        c_acc, t_run, done, d_acc, w_acc, td_max, n_alive = carry
        alive = jnp.any(~done)
        mx, my = sl["m"][:, 0], sl["m"][:, 1]
        ca, cb, cc = sl["c"][:, 0], sl["c"][:, 1], sl["c"][:, 2]
        dx = px[:, None] - mx[None, :]
        dy = py[:, None] - my[None, :]
        power = (-0.5 * (ca[None] * dx * dx + cc[None] * dy * dy)
                 - cb[None] * dx * dy)
        alpha = jnp.minimum(sl["o"][None, :] * jnp.exp(power), ALPHA_MAX)
        alpha = jnp.where(alpha >= ALPHA_MIN, alpha, 0.0)
        cp = jnp.cumprod(1.0 - alpha, axis=1)
        tp = t_run[:, None] * cp
        t_before = t_run[:, None] * jnp.concatenate(
            [jnp.ones_like(cp[:, :1]), cp[:, :-1]], axis=1)
        blend = (tp >= T_EPS) & (~done[:, None])    # sticky done, see kernel
        w = jnp.where(blend, alpha * t_before, 0.0)
        c_acc = c_acc + w @ sl["rgb"]
        d_acc = d_acc + jnp.sum(w * sl["d"][None, :], axis=1)
        w_acc = w_acc + jnp.sum(w, axis=1)
        td_max = jnp.maximum(td_max, jnp.max(
            jnp.where(blend & (alpha > 0.0), sl["d"][None, :], 0.0), axis=1))
        t_run = jnp.min(jnp.where(blend, tp, t_run[:, None]), axis=1)
        done = done | (tp[:, -1] < T_EPS)
        n_alive = n_alive + alive.astype(jnp.int32)
        # Per-lane blend contribution: sum of w over the tile's pixels —
        # identical math to the fused kernel's accumulator, so the two
        # impls agree bit-for-bit on matching inputs.
        return (c_acc, t_run, done, d_acc, w_acc, td_max, n_alive), \
            jnp.sum(w, axis=0)

    n_chunks = k // chunk
    xs = {
        "m": mean2d.reshape(n_chunks, chunk, 2),
        "c": conic.reshape(n_chunks, chunk, 3),
        "rgb": rgb.reshape(n_chunks, chunk, 3),
        "o": opacity.reshape(n_chunks, chunk),
        "d": depth.reshape(n_chunks, chunk),
    }
    init = (jnp.zeros((p, 3)), jnp.ones((p,)), jnp.zeros((p,), bool),
            jnp.zeros((p,)), jnp.zeros((p,)), jnp.zeros((p,)), jnp.int32(0))
    (c_acc, t_run, done, d_acc, w_acc, td_max, n_alive), contrib = \
        jax.lax.scan(body, init, xs)
    processed = jnp.minimum(n_alive * chunk, count).astype(jnp.int32)
    return (c_acc.reshape(tile, tile, 3), t_run.reshape(tile, tile),
            (d_acc / jnp.maximum(w_acc, 1e-8)).reshape(tile, tile),
            td_max.reshape(tile, tile), processed, contrib.reshape(k))


@functools.partial(jax.jit, static_argnames=("impl", "chunk", "tile"))
def raster_tiles(mean2d, conic, rgb, opacity, depth, origins, counts,
                 *, impl: str = "jnp_chunked", chunk: int = 64,
                 tile: int = TILE, slot_active=None):
    """Rasterize a batch of tiles: inputs (R, K, ...) -> 6 outputs.

    The leading axis is whatever tile set the caller planned — all T
    tiles on the dense path, or a TilePlan's R compacted slots (the
    production path in core/pipeline.py, where raster cost scales with
    the re-render slot count). Returns (rgb, transmittance,
    expected_depth, truncated_depth, processed_pairs, lane_contrib):
    ``processed_pairs`` is (R,) int32 pairs traversed before the
    early-stop exit (chunk-granular for pallas/jnp_chunked, exact for
    ref); ``lane_contrib`` is (R, K) float32 per-lane blend contribution
    — the sum of blend weights ``alpha * T_before`` over the tile's
    pixels, reported in INPUT lane order on every impl (the fused kernel
    unscrambles its in-kernel sort), exactly 0 for padding / masked /
    never-blended lanes. It is the temporal-prior statistic
    ``core/culling.py`` thresholds on (DESIGN.md §12).

    ``slot_active`` (R,) bool is the TilePlan slot mask, consumed only by
    ``impl="pallas_fused"`` (masked slots skip the in-kernel sort).
    Contract: an inactive slot has ``counts == 0`` — the plan pipeline
    guarantees it by masking intersections with ``plan.slot_active``
    before binning — so every impl renders it as empty and the mask is a
    cost hint, not a semantic input (DESIGN.md §9).
    """
    with annotate(f"repro.raster/{impl}"):
        if impl == "pallas_fused":
            return raster_plan_fused(mean2d, conic, rgb, opacity, depth,
                                     origins, counts, slot_active,
                                     chunk=chunk, tile=tile,
                                     interpret=not _on_tpu())
        if impl == "pallas":
            return raster_tiles_pallas(mean2d, conic, rgb, opacity, depth,
                                       origins, counts, chunk=chunk,
                                       tile=tile, interpret=not _on_tpu())
        if impl == "jnp_chunked":
            fn = functools.partial(_raster_tile_chunked_jnp, chunk=chunk,
                                   tile=tile)
            return jax.vmap(fn)(mean2d, conic, rgb, opacity, depth,
                                origins, counts)
        if impl == "ref":
            return ref_kernels.raster_tiles_ref(mean2d, conic, rgb,
                                                opacity, depth, origins,
                                                tile=tile)
    raise ValueError(f"unknown impl {impl!r}")


@functools.partial(jax.jit, static_argnames=("block_n",))
def _preprocess_geom_pallas_jit(means, log_scales, quats, opacity, w2c,
                                intrin, *, block_n: int):
    return preprocess_geom_pallas(means, log_scales, quats, opacity, w2c,
                                  intrin, block_n=block_n,
                                  interpret=not _on_tpu())


def preprocess_geom(means, log_scales, quats, opacity, w2c, intrin,
                    *, impl: str = "pallas", block_n: int = 256):
    """Fused CCU preprocess. See kernels/preprocess.py for outputs.

    ``impl="ref"`` requires concrete (non-traced) ``intrin`` since the
    oracle builds a static Camera; it is meant for tests.
    """
    if impl == "pallas":
        return _preprocess_geom_pallas_jit(means, log_scales, quats, opacity,
                                           w2c, intrin, block_n=block_n)
    if impl == "ref":
        return ref_kernels.preprocess_geom_ref(means, log_scales, quats,
                                               opacity, w2c, intrin)
    raise ValueError(f"unknown impl {impl!r}")
