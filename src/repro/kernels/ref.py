"""Pure-jnp oracles for every Pallas kernel in this package.

Semantics match the reference 3DGS CUDA rasterizer exactly:
  - per-Gaussian alpha = min(0.99, opacity * exp(power)); skipped (no state
    update) when alpha < 1/255;
  - front-to-back blending, and a pixel is *done* at the first Gaussian
    whose blend would push transmittance below 1e-4 — that Gaussian is NOT
    blended (the CUDA code `continue`s before accumulating);
  - outputs: blended rgb, final transmittance, normalized opacity-weighted
    expected depth (the paper's real-time depth estimate, Sec. IV-A), the
    truncated depth (depth of the last blended Gaussian, Sec. IV-B), the
    processed-pair count, and the per-lane blend contribution (the sum of
    blend weights ``alpha * T_before`` over the tile's pixels — the
    temporal-prior statistic contribution culling thresholds on).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.camera import TILE

ALPHA_MIN = 1.0 / 255.0
ALPHA_MAX = 0.99
T_EPS = 1e-4


def _pixel_coords(origin: jax.Array, tile: int = TILE) -> Tuple[jax.Array, jax.Array]:
    """Pixel-center coords of one tile. origin: (2,) -> (tile*tile,) each."""
    ii = jnp.arange(tile, dtype=jnp.float32)
    py, px = jnp.meshgrid(ii, ii, indexing="ij")
    px = px.ravel() + origin[0] + 0.5
    py = py.ravel() + origin[1] + 0.5
    return px, py


def raster_tile_ref(mean2d: jax.Array, conic: jax.Array, rgb: jax.Array,
                    opacity: jax.Array, depth: jax.Array, origin: jax.Array,
                    *, tile: int = TILE):
    """Rasterize ONE tile by sequential scan over its K sorted Gaussians.

    mean2d (K,2), conic (K,3), rgb (K,3), opacity (K,), depth (K,),
    origin (2,). Invalid entries must have opacity == 0.
    Returns rgb (tile,tile,3), trans (tile,tile), exp_depth (tile,tile),
    trunc_depth (tile,tile), processed (), lane_contrib (K,) — the
    per-lane sum of blend weights over the tile's pixels (exactly 0 for
    padding / never-blended lanes).
    """
    px, py = _pixel_coords(origin, tile)
    p = tile * tile

    def body(carry, g):
        color, trans, done, dacc, wacc, tdepth, n_proc = carry
        m, con, c, o, d = g
        # Tile-level traversal work: this entry is processed if it is a real
        # (non-padding) pair and at least one pixel is still alive.
        alive_any = jnp.any(~done)
        n_proc = n_proc + (alive_any & (o > 0.0)).astype(jnp.int32)
        dx = px - m[0]
        dy = py - m[1]
        power = -0.5 * (con[0] * dx * dx + con[2] * dy * dy) - con[1] * dx * dy
        alpha = jnp.minimum(o * jnp.exp(power), ALPHA_MAX)
        alpha = jnp.where(alpha >= ALPHA_MIN, alpha, 0.0)
        test_t = trans * (1.0 - alpha)
        # CUDA semantics: the `done` flag is STICKY — the gaussian that
        # would push T below 1e-4 is dropped and the pixel never blends
        # again, even for later tiny alphas.
        trigger = (alpha > 0.0) & (test_t < T_EPS)
        blend = (alpha > 0.0) & ~done & ~trigger
        w = jnp.where(blend, alpha * trans, 0.0)
        color = color + w[:, None] * c[None, :]
        dacc = dacc + w * d
        wacc = wacc + w
        tdepth = jnp.where(blend, jnp.maximum(tdepth, d), tdepth)
        trans = jnp.where(blend, test_t, trans)
        done = done | trigger
        return (color, trans, done, dacc, wacc, tdepth, n_proc), jnp.sum(w)

    init = (jnp.zeros((p, 3)), jnp.ones((p,)), jnp.zeros((p,), bool),
            jnp.zeros((p,)), jnp.zeros((p,)), jnp.zeros((p,)), jnp.int32(0))
    (color, trans, done, dacc, wacc, tdepth, n_proc), contrib = jax.lax.scan(
        init=init, xs=(mean2d, conic, rgb, opacity, depth), f=body)
    exp_depth = dacc / jnp.maximum(wacc, 1e-8)
    shape = (tile, tile)
    return (color.reshape(tile, tile, 3), trans.reshape(shape),
            exp_depth.reshape(shape), tdepth.reshape(shape), n_proc, contrib)


def raster_tiles_ref(mean2d, conic, rgb, opacity, depth, origins, *, tile: int = TILE):
    """vmap of ``raster_tile_ref`` over tiles: inputs (T, K, ...) -> (T, tile, tile, ...)."""
    fn = lambda m, co, c, o, d, org: raster_tile_ref(m, co, c, o, d, org, tile=tile)
    return jax.vmap(fn)(mean2d, conic, rgb, opacity, depth, origins)


def preprocess_geom_ref(means, log_scales, quats, opacity, w2c, intrin, *,
                        dilation: float = 0.3, near: float = 0.05,
                        frustum_margin: float = 1.3):
    """Oracle for the fused CCU preprocess kernel (geometry only, no SH).

    means (N,3), log_scales (N,3), quats (N,4), opacity (N,), w2c (4,4),
    intrin (6,) = [fx, fy, cx, cy, width, height].
    Returns mean2d (N,2), conic (N,3), depth (N,), aux (N,6) =
    [radius3, r_major, r_minor, half_w, half_h, valid], minor_axis (N,2).
    Mirrors core/projection.py::preprocess — kept separate so the kernel has
    a self-contained oracle over raw arrays.
    """
    from repro.core.gaussians import GaussianScene
    from repro.core.projection import preprocess
    from repro.core.camera import Camera

    fx, fy, cx, cy, w, h = [float(x) for x in intrin]
    sh = jnp.zeros((means.shape[0], 1, 3), means.dtype)
    logit = jnp.log(opacity / jnp.maximum(1.0 - opacity, 1e-8))
    scene = GaussianScene(means, log_scales, quats, logit, sh)
    cam = Camera(w2c=w2c, fx=fx, fy=fy, cx=cx, cy=cy, width=int(w), height=int(h))
    pr = preprocess(scene, cam, near=near, frustum_margin=frustum_margin,
                    dilation=dilation)
    aux = jnp.stack([pr.radius3, pr.r_major, pr.r_minor,
                     pr.tight_half_wh[:, 0], pr.tight_half_wh[:, 1],
                     pr.valid.astype(means.dtype)], axis=-1)
    return pr.mean2d, pr.conic, pr.depth, aux, pr.minor_axis


def tile_sort_ref(keys: jax.Array, values: jax.Array):
    """Oracle for the per-tile bitonic sorter: ascending sort of each row.

    keys (T, K) float, values (T, K) int32. Returns sorted (keys, values).
    """
    order = jnp.argsort(keys, axis=-1, stable=True)
    return jnp.take_along_axis(keys, order, axis=-1), \
        jnp.take_along_axis(values, order, axis=-1)
