"""Pallas TPU kernel for the tile rasterizer (the paper's VRU).

Hardware mapping (DESIGN.md §3): one grid step per 16x16 tile; the tile's
K depth-sorted Gaussians live in VMEM as (K, attr) blocks; blending is
vectorized as (256 pixels x G-chunk) with an exact per-pixel prefix-product
transmittance, so the math is bit-for-bit the sequential CUDA semantics
(see kernels/ref.py). Early stopping is chunk-granular: a `while_loop`
terminates a tile as soon as every pixel's transmittance fell below 1e-4
or the tile's valid count is exhausted — this is what DPES's per-tile
workload prediction (count) feeds.

The (P, G) @ (G, 3) color accumulation is an MXU matmul; everything else is
VPU elementwise. VMEM footprint per tile at K=1024, G=64:
K * 10 attrs * 4B = 40 KiB resident + ~512 KiB chunk intermediates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.camera import TILE

ALPHA_MIN = 1.0 / 255.0
ALPHA_MAX = 0.99
T_EPS = 1e-4


def _raster_kernel(mean_ref, conic_ref, rgb_ref, opac_ref, depth_ref,
                   origin_ref, count_ref,
                   rgb_out, trans_out, depth_out, tdepth_out, processed_out,
                   contrib_out, *, k: int, chunk: int, tile: int):
    p = tile * tile
    ox = origin_ref[0, 0]
    oy = origin_ref[0, 1]
    iy = jax.lax.broadcasted_iota(jnp.float32, (tile, tile), 0)
    ix = jax.lax.broadcasted_iota(jnp.float32, (tile, tile), 1)
    px = (ix + ox + 0.5).reshape(p)
    py = (iy + oy + 0.5).reshape(p)

    n_chunks = k // chunk
    count = count_ref[0]
    used_chunks = jnp.minimum((count + chunk - 1) // chunk, n_chunks)

    def chunk_body(state):
        i, c_acc, t_run, done, d_acc, w_acc, td_max, contrib = state
        sl = pl.ds(i * chunk, chunk)
        mx = mean_ref[0, sl, 0]                     # (G,)
        my = mean_ref[0, sl, 1]
        ca = conic_ref[0, sl, 0]
        cb = conic_ref[0, sl, 1]
        cc = conic_ref[0, sl, 2]
        col = rgb_ref[0, sl, :]                     # (G, 3)
        op = opac_ref[0, sl]                        # (G,)
        dep = depth_ref[0, sl]                      # (G,)

        dx = px[:, None] - mx[None, :]              # (P, G)
        dy = py[:, None] - my[None, :]
        power = (-0.5 * (ca[None, :] * dx * dx + cc[None, :] * dy * dy)
                 - cb[None, :] * dx * dy)
        alpha = jnp.minimum(op[None, :] * jnp.exp(power), ALPHA_MAX)
        alpha = jnp.where(alpha >= ALPHA_MIN, alpha, 0.0)

        factors = 1.0 - alpha
        cp = jnp.cumprod(factors, axis=1)           # inclusive prefix (P, G)
        tp = t_run[:, None] * cp                    # T after blending j
        t_before = t_run[:, None] * jnp.concatenate(
            [jnp.ones_like(cp[:, :1]), cp[:, :-1]], axis=1)
        # tp is monotone within the chunk, so (tp >= eps) is exactly the
        # sequential sticky-done prefix; the ~done gate carries stickiness
        # across chunks (CUDA drops the triggering gaussian and never
        # blends that pixel again).
        blend = (tp >= T_EPS) & (~done[:, None])
        w = jnp.where(blend, alpha * t_before, 0.0)  # (P, G)

        c_acc = c_acc + w @ col                     # (P, 3) MXU
        d_acc = d_acc + jnp.sum(w * dep[None, :], axis=1)
        w_acc = w_acc + jnp.sum(w, axis=1)
        td_max = jnp.maximum(
            td_max, jnp.max(jnp.where(blend & (alpha > 0.0), dep[None, :], 0.0),
                            axis=1))
        t_run = jnp.min(jnp.where(blend, tp, t_run[:, None]), axis=1)
        done = done | (tp[:, -1] < T_EPS)
        # Per-lane contribution (sum of w over pixels) — slice update, no
        # scatter, so the kernel stays gather/scatter-free.
        contrib = jax.lax.dynamic_update_slice_in_dim(
            contrib, jnp.sum(w, axis=0), i * chunk, axis=0)
        return i + 1, c_acc, t_run, done, d_acc, w_acc, td_max, contrib

    def chunk_cond(state):
        i, _, _, done, _, _, _, _ = state
        return (i < used_chunks) & jnp.any(~done)

    init = (jnp.int32(0),
            jnp.zeros((p, 3), jnp.float32),
            jnp.ones((p,), jnp.float32),
            jnp.zeros((p,), bool),
            jnp.zeros((p,), jnp.float32),
            jnp.zeros((p,), jnp.float32),
            jnp.zeros((p,), jnp.float32),
            jnp.zeros((k,), jnp.float32))
    (n_done, c_acc, t_run, done, d_acc, w_acc, td_max,
     contrib) = jax.lax.while_loop(chunk_cond, chunk_body, init)

    rgb_out[0] = c_acc.reshape(tile, tile, 3)
    trans_out[0] = t_run.reshape(tile, tile)
    depth_out[0] = (d_acc / jnp.maximum(w_acc, 1e-8)).reshape(tile, tile)
    tdepth_out[0] = td_max.reshape(tile, tile)
    # Pairs actually traversed before the chunk-granular early exit — the
    # simulator's raster work term (DPES's target quantity).
    processed_out[0] = jnp.minimum(n_done * chunk, count)
    contrib_out[0] = contrib


def raster_tiles_pallas(mean2d, conic, rgb, opacity, depth, origins, counts,
                        *, chunk: int = 64, tile: int = TILE,
                        interpret: bool = True):
    """Rasterize all tiles. Inputs (T, K, ...) as produced by binning.

    Returns rgb (T, tile, tile, 3), trans, exp_depth, trunc_depth
    (each (T, tile, tile)), processed (T,) int32, lane_contrib (T, K).
    Lanes here are pre-sorted, so the contribution comes back in input
    lane order with no unscrambling.
    """
    t, k = opacity.shape
    if k % chunk:
        raise ValueError(f"capacity K={k} must be a multiple of chunk={chunk}")
    kernel = functools.partial(_raster_kernel, k=k, chunk=chunk, tile=tile)
    f32 = jnp.float32
    out_shapes = (
        jax.ShapeDtypeStruct((t, tile, tile, 3), f32),
        jax.ShapeDtypeStruct((t, tile, tile), f32),
        jax.ShapeDtypeStruct((t, tile, tile), f32),
        jax.ShapeDtypeStruct((t, tile, tile), f32),
        jax.ShapeDtypeStruct((t,), jnp.int32),
        jax.ShapeDtypeStruct((t, k), f32),
    )
    grid = (t,)
    in_specs = [
        pl.BlockSpec((1, k, 2), lambda i: (i, 0, 0)),
        pl.BlockSpec((1, k, 3), lambda i: (i, 0, 0)),
        pl.BlockSpec((1, k, 3), lambda i: (i, 0, 0)),
        pl.BlockSpec((1, k), lambda i: (i, 0)),
        pl.BlockSpec((1, k), lambda i: (i, 0)),
        pl.BlockSpec((1, 2), lambda i: (i, 0)),
        pl.BlockSpec((1,), lambda i: (i,)),
    ]
    out_specs = (
        pl.BlockSpec((1, tile, tile, 3), lambda i: (i, 0, 0, 0)),
        pl.BlockSpec((1, tile, tile), lambda i: (i, 0, 0)),
        pl.BlockSpec((1, tile, tile), lambda i: (i, 0, 0)),
        pl.BlockSpec((1, tile, tile), lambda i: (i, 0, 0)),
        pl.BlockSpec((1,), lambda i: (i,)),
        pl.BlockSpec((1, k), lambda i: (i, 0)),
    )
    return pl.pallas_call(
        kernel, grid=grid, in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shapes, interpret=interpret,
    )(mean2d.astype(f32), conic.astype(f32), rgb.astype(f32),
      opacity.astype(f32), depth.astype(f32),
      origins.astype(f32), counts.astype(jnp.int32))
