"""Batched serving launcher: continuous decode over a request queue.

Single-host reference implementation of the serving loop the decode dry-run
cells lower: fixed-size batch slots, each slot holds an independent request;
finished slots are refilled from the queue (continuous batching). The KV
cache is allocated once at ``--max-seq`` and reused across requests —
the LS-Gaussian "reuse, don't recompute" principle applied to LM serving
(DESIGN.md §4).

  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import model as M


def serve(cfg, *, batch_slots: int, max_seq: int, n_requests: int,
          prompt_len: int, max_new: int, seed: int = 0) -> dict:
    params = M.init_params(jax.random.PRNGKey(seed), cfg)
    step = jax.jit(lambda p, t, c: M.decode_step(p, t, c, cfg))

    rng = np.random.default_rng(seed)
    queue = [rng.integers(0, cfg.vocab_size, prompt_len).tolist()
             for _ in range(n_requests)]
    done, active = [], {}
    cache = M.init_cache(cfg, batch_slots, max_seq)
    # per-slot progress bookkeeping (host side)
    slot_tokens = np.zeros((batch_slots,), np.int64)
    slot_left = np.zeros((batch_slots,), np.int64)
    cur = np.zeros((batch_slots, 1), np.int32)

    def refill():
        for s in range(batch_slots):
            if slot_left[s] == 0 and queue:
                prompt = queue.pop()
                # feed prompt token-by-token (reference loop; prefill path
                # covers the fused variant)
                cur[s, 0] = prompt[0]
                slot_left[s] = len(prompt) - 1 + max_new
                slot_tokens[s] = 0

    refill()
    t0 = time.time()
    steps = 0
    while np.any(slot_left > 0):
        logits, cache = step(params, jnp.asarray(cur), cache)
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        for s in range(batch_slots):
            if slot_left[s] > 0:
                cur[s, 0] = nxt[s]
                slot_left[s] -= 1
                slot_tokens[s] += 1
                if slot_left[s] == 0:
                    done.append(int(slot_tokens[s]))
        steps += 1
        refill()
        if steps >= max_seq - 1:
            break
    dt = time.time() - t0
    total = int(np.sum(slot_tokens)) + sum(done) if not done else sum(done)
    return {"requests_done": len(done), "decode_steps": steps,
            "tok_per_s": total / dt if dt > 0 else 0.0,
            "wall_s": dt}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="yi-9b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=64)
    args = ap.parse_args()
    cfg = get_config(args.arch).reduced()
    out = serve(cfg, batch_slots=args.slots, max_seq=args.max_seq,
                n_requests=args.requests, prompt_len=args.prompt_len,
                max_new=args.max_new)
    print(out)


if __name__ == "__main__":
    main()
