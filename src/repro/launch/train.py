"""Fault-tolerant training driver (end-to-end).

Features exercised by tests/examples:
  - auto-resume: picks up the latest checkpoint (params+opt+step+data
    cursor) — restart-after-kill continues the exact token stream;
  - periodic atomic checkpoints (train/checkpoint.py);
  - straggler/step watchdog: a step exceeding ``step_timeout_s`` is
    logged and counted (on real fleets this triggers pod replacement;
    single-process here, so mitigation = surfacing, DESIGN.md §5);
  - optional mesh: when devices allow, the same driver runs sharded with
    the production sharding rules; CPU runs single-device.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --smoke \
      --steps 200 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.train import checkpoint as ckpt
from repro.train.data import DataConfig, batch_at
from repro.train.optimizer import OptimizerConfig
from repro.train import train_step as TS


@dataclasses.dataclass
class RunConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    step_timeout_s: float = 300.0


def train_loop(cfg, data_cfg: DataConfig, opt_cfg: OptimizerConfig,
               run: RunConfig, *, mesh=None, log=print) -> dict:
    step_fn = jax.jit(TS.make_train_step(cfg, opt_cfg, mesh),
                      donate_argnums=(0,))

    start_step = 0
    state = None
    if run.ckpt_dir and ckpt.latest_step(run.ckpt_dir) is not None:
        template = jax.eval_shape(
            lambda: TS.init_train_state(jax.random.PRNGKey(0), cfg))
        shardings = None
        if mesh is not None:
            from repro.distributed.sharding import param_shardings
            shardings = param_shardings(template, mesh)
        state, start_step, meta = ckpt.restore(run.ckpt_dir, template,
                                               shardings=shardings)
        log(f"[resume] restored step {start_step} "
            f"(loss was {meta.get('loss', '?')})")
    if state is None:
        state = TS.init_train_state(jax.random.PRNGKey(data_cfg.seed), cfg)
        if mesh is not None:
            from repro.distributed.sharding import param_shardings
            state = jax.device_put(state, param_shardings(state, mesh))

    history = []
    stragglers = 0
    last_loss = float("nan")
    for step in range(start_step, run.steps):
        t0 = time.time()
        batch = batch_at(data_cfg, step)
        state, metrics = step_fn(state, batch)
        dt = time.time() - t0
        if dt > run.step_timeout_s:
            stragglers += 1
            log(f"[watchdog] step {step} took {dt:.1f}s "
                f"(> {run.step_timeout_s}s) — straggler #{stragglers}")
        last_loss = float(metrics["loss"])
        if step % run.log_every == 0 or step == run.steps - 1:
            log(f"step {step:5d} loss {last_loss:.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} "
                f"lr {float(metrics['lr']):.2e} {dt * 1e3:.0f}ms")
        history.append(last_loss)
        if run.ckpt_dir and (step + 1) % run.ckpt_every == 0:
            ckpt.save(run.ckpt_dir, step + 1, state,
                      metadata={"loss": last_loss, "arch": cfg.name})
    if run.ckpt_dir:
        ckpt.save(run.ckpt_dir, run.steps, state,
                  metadata={"loss": last_loss, "arch": cfg.name})
    return {"final_loss": last_loss, "history": history,
            "stragglers": stragglers, "state": state}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="yi-9b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    data_cfg = DataConfig(batch_size=args.batch, seq_len=args.seq,
                          vocab_size=cfg.vocab_size)
    opt_cfg = OptimizerConfig(total_steps=args.steps,
                              warmup_steps=max(args.steps // 20, 1))
    run = RunConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                    ckpt_dir=args.ckpt_dir)
    out = train_loop(cfg, data_cfg, opt_cfg, run)
    print(json.dumps({"final_loss": out["final_loss"],
                      "stragglers": out["stragglers"]}))


if __name__ == "__main__":
    main()
