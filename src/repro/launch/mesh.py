"""Production mesh builders (function, not module constant — importing this
module must never touch jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 2):
    """Small mesh over host devices for tests (subprocess sets device count)."""
    return jax.make_mesh((data, model), ("data", "model"))
