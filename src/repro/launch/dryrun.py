"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: 512 host
placeholder devices stand in for 2 pods x 256 chips. For each cell we emit
a JSON artifact (memory analysis, FLOPs/bytes, per-collective byte counts)
that §Roofline and §Perf read.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--single-pod]
"""
# The XLA device-count override MUST precede any jax-touching import —
# device count locks on first backend init. Do not move these lines.
import os  # noqa: E402
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from typing import Any, Dict, Optional, Tuple  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np   # noqa: E402
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCH_IDS, get_config, get_shape  # noqa: E402
from repro.configs.base import SHAPES, shape_applicable    # noqa: E402
from repro.distributed import sharding as shard            # noqa: E402
from repro.launch.mesh import make_production_mesh          # noqa: E402
from repro.models import model as M                         # noqa: E402
from repro.models import sharding_hooks as hooks            # noqa: E402
from repro.train.optimizer import OptimizerConfig           # noqa: E402
from repro.train import train_step as TS                    # noqa: E402


def make_hooks(cfg, shape, mesh: Mesh,
               overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Activation constraints + execution flags for one cell."""
    h: Dict[str, Any] = {}
    baxes = shard.batch_axes(mesh)
    model_size = mesh.shape["model"]
    if shape.kind in ("train", "prefill") and cfg.family != "renderer":
        if shape.seq_len % model_size == 0:
            if cfg.family == "moe" and cfg.d_model % model_size == 0:
                # §Perf cell A iter 4: MoE residuals shard d (not seq) —
                # row-local dispatch otherwise re-gathers seq every layer.
                h["residual"] = NamedSharding(mesh, P(baxes, None, "model"))
            else:
                h["residual"] = NamedSharding(mesh, P(baxes, "model", None))
            h["attn_scores_gqa"] = NamedSharding(
                mesh, P(baxes, None, None, "model", None))
            h["attn_scores_mla"] = NamedSharding(
                mesh, P(baxes, None, "model", None))
    h["attn_impl"] = "sdpa" if shape.kind == "train" else \
        ("flash" if shape.kind == "prefill" else "auto")
    # §Perf cell A iter 3: expert buffers (B, E, C, d) — rows over the
    # data axes, experts over "model"; the combine is all-to-all-shaped.
    # Default ON for MoE (override {"moe_ep": False} reproduces iter 2).
    moe_ep = cfg.family == "moe" and cfg.num_experts % model_size == 0
    if overrides:
        ov = dict(overrides)            # never mutate the caller's dict
        moe_ep = ov.pop("moe_ep", moe_ep)
        h.update(ov)
    if moe_ep:
        h["moe_buf"] = NamedSharding(mesh, P(baxes, "model", None, None))
        h["moe_buf_decode"] = NamedSharding(mesh, P("model", None, None))
    return h

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "experiments", "artifacts")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|s64|f64)"
                       r"\[([0-9,]*)\]")
_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
          "u8": 1, "pred": 1, "s64": 8, "f64": 8}


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum result-shape bytes of every collective op in the (SPMD) HLO."""
    out = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r".*= *(\([^)]*\)|\S+) *(" + "|".join(_COLLECTIVES)
                     + r")\(", stripped)
        if not m:
            continue
        op = m.group(2)
        result_type = m.group(1)
        total = 0.0
        for dt, dims in _SHAPE_RE.findall(result_type):
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            total += n * _BYTES[dt]
        out[op] += total
        counts[op] += 1
    out["counts"] = counts  # type: ignore
    return out


def _struct(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def input_specs(cfg, shape, *, for_decode: bool = False) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    b = shape.global_batch
    s = 1 if for_decode else shape.seq_len
    d = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if not for_decode:
        d["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if cfg.family == "encdec":
        d["frames"] = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model),
                                           jnp.bfloat16)
    if cfg.family == "vlm":
        d["vision"] = jax.ShapeDtypeStruct((b, cfg.num_vision_tokens,
                                            cfg.d_model), jnp.bfloat16)
    if for_decode:
        d.pop("labels", None)
    return d


def build_cell(cfg, shape, mesh: Mesh):
    """Returns (fn, args_structs, in_shardings, out_shardings)."""
    opt_cfg = OptimizerConfig()

    if shape.kind == "train":
        batch = input_specs(cfg, shape)
        state = jax.eval_shape(
            lambda: TS.init_train_state(jax.random.PRNGKey(0), cfg))
        fn = TS.make_train_step(cfg, opt_cfg, mesh)
        state_sh = shard.param_shardings(state, mesh)
        batch_sh = shard.batch_shardings(batch, mesh)
        out_sh = (state_sh, shard.replicated(
            jax.eval_shape(lambda s, b: fn(s, b)[1], state, batch), mesh))
        # donate the train state (params + opt) — matches launch/train.py.
        return fn, (state, batch), (state_sh, batch_sh), out_sh, (0,)

    if shape.kind == "prefill":
        batch = input_specs(cfg, shape)
        batch.pop("labels")
        params = jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0),
                                                      cfg))

        def fn(p, bt):
            logits, aux, cache = M.forward(p, bt, cfg, build_cache=True)
            return logits, cache

        p_sh = shard.param_shardings(params, mesh)
        b_sh = shard.batch_shardings(batch, mesh)
        out_struct = jax.eval_shape(fn, params, batch)
        vocab_axis = "model" if cfg.vocab_size % mesh.shape["model"] == 0 \
            else None
        logits_sh = NamedSharding(
            mesh, P(shard.batch_axes(mesh), None, vocab_axis))
        cache_sh = shard.cache_shardings(out_struct[1], mesh)
        return fn, (params, batch), (p_sh, b_sh), (logits_sh, cache_sh), ()

    # decode
    batch = input_specs(cfg, shape, for_decode=True)
    params = jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))
    enc = None
    if cfg.family == "encdec":
        enc = jax.ShapeDtypeStruct(
            (shape.global_batch, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    cache = jax.eval_shape(
        lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len,
                             enc_out=None))
    if enc is not None:
        cache = cache._replace(enc_out=enc)

    def fn(p, toks, c):
        return M.decode_step(p, toks, c, cfg)

    p_sh = shard.param_shardings(params, mesh)
    t_sh = shard.batch_shardings(batch, mesh)["tokens"]
    c_sh = shard.cache_shardings(cache, mesh)
    out_struct = jax.eval_shape(fn, params, batch["tokens"], cache)
    logits_sh = shard.batch_shardings(
        {"x": out_struct[0]}, mesh)["x"]
    # donate the cache: without it every decode step materializes a full
    # copy of the KV cache (measured: +87 GiB/dev on whisper decode_32k).
    return (fn, (params, batch["tokens"], cache), (p_sh, t_sh, c_sh),
            (logits_sh, c_sh), (2,))


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             save: bool = True,
             hook_overrides: Optional[Dict[str, Any]] = None,
             cfg_override=None, tag: str = "") -> Dict[str, Any]:
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    shape = get_shape(shape_name)
    ok, why = shape_applicable(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "family": cfg.family, "status": "skipped", "reason": why,
    }
    if not ok:
        _save(result, save)
        return result

    if tag:
        result["tag"] = tag
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        fn, args, in_sh, out_sh, donate = build_cell(cfg, shape, mesh)
        hooks.set_hooks(make_hooks(cfg, shape, mesh, hook_overrides))
        try:
            with mesh:
                jitted = jax.jit(fn, in_shardings=in_sh,
                                 out_shardings=out_sh,
                                 donate_argnums=donate)
                lowered = jitted.lower(*args)
                t_lower = time.time() - t0
                compiled = lowered.compile()
                t_compile = time.time() - t0 - t_lower
        finally:
            hooks.set_hooks({})

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)

        result.update({
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "flops": float(cost.get("flops", -1)) if cost else -1,
            "bytes_accessed": float(cost.get("bytes accessed", -1))
            if cost else -1,
            "collective_bytes": {k: v for k, v in coll.items()
                                 if k != "counts"},
            "collective_counts": coll["counts"],
            "memory": {
                k: getattr(mem, k) for k in
                ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes")
                if mem is not None and hasattr(mem, k)
            },
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count(),
            "tokens": shape.tokens if shape.kind != "decode"
            else shape.global_batch,
            "kind": shape.kind,
        })
    except Exception as e:  # noqa: BLE001 — dry-run reports, caller decides
        result.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-3000:]})
    _save(result, save)
    return result


def _save(result: Dict[str, Any], save: bool) -> None:
    if not save:
        return
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    name = f"dryrun_{result['arch']}_{result['shape']}_{result['mesh']}.json"
    with open(os.path.join(ARTIFACT_DIR, name), "w") as f:
        json.dump(result, f, indent=1)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS + ("all",), default="all")
    ap.add_argument("--shape", default="all",
                    choices=[s.name for s in SHAPES] + ["all"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else (args.arch,)
    shapes = [s.name for s in SHAPES] if args.shape == "all" else (args.shape,)
    meshes = []
    if args.single_pod or not args.multi_pod:
        meshes.append(False)
    if args.multi_pod:
        meshes.append(True)

    n_fail = 0
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                r = run_cell(arch, shape_name, multi_pod=mp)
                tag = r["status"].upper()
                extra = r.get("error", r.get("reason", ""))
                print(f"[{tag:7s}] {arch:26s} {shape_name:12s} "
                      f"{r['mesh']:10s} "
                      f"compile={r.get('compile_s', '-')}s {extra}",
                      flush=True)
                if r["status"] == "error":
                    n_fail += 1
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
