"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Hardware model (TPU v5e-like, fixed constants):
  peak        197 TFLOP/s bf16 per chip
  HBM         819 GB/s per chip
  ICI         ~50 GB/s per link

Three terms per (arch x shape x mesh):
  compute    = HLO_FLOPs / (chips * peak)
  memory     = HLO_bytes / (chips * hbm_bw)
  collective = collective_bytes / (chips * link_bw)

IMPORTANT CAVEAT + CORRECTION: XLA's HloCostAnalysis visits a while-loop
body ONCE — scanned layer stacks are undercounted by ~L x. We correct by
compiling each cell at two extra scan lengths (same dims, L1 < L2 layers)
and extrapolating: per_unit = (cost(L2) - cost(L1)) / (units2 - units1);
corrected(L) = cost(L1) + (units(L) - units1) * per_unit. The same
correction applies to collective bytes (collectives inside the scanned
body execute once per layer). Raw and corrected values are both reported.

MODEL_FLOPS = 6*N*D for training (2*N*D inference) with N = active params
(MoE) plus causal attention-score FLOPs; the usefulness ratio
MODEL_FLOPS / HLO_FLOPs flags remat/redundancy waste.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
from typing import Any, Dict, Optional, Tuple

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # B/s / chip
ICI_BW = 50e9              # B/s / link

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "experiments", "artifacts")


def _correction_layers(cfg) -> Optional[Tuple[int, int, int, int, int]]:
    """(L1, L2, units1, units2, units_full) for the 2-point correction."""
    if cfg.family == "hybrid":
        k = cfg.shared_attn_every
        tail = cfg.num_layers - (cfg.num_layers // k) * k
        return (k + tail, 2 * k + tail, 1, 2, cfg.num_layers // k)
    return (1, 2, 1, 2, cfg.num_layers)


def corrected_cell(arch: str, shape_name: str, *, multi_pod: bool,
                   hook_overrides=None, cfg_override=None,
                   tag: str = "") -> Dict[str, Any]:
    """Run full cell + two mini-compiles; emit corrected roofline terms."""
    import dataclasses as dc

    from repro.configs import get_config
    from repro.launch import dryrun

    cfg = cfg_override if cfg_override is not None else get_config(arch)
    full = dryrun.run_cell(arch, shape_name, multi_pod=multi_pod,
                           hook_overrides=hook_overrides,
                           cfg_override=cfg, tag=tag)
    if full["status"] != "ok":
        return full

    corr = _correction_layers(cfg)
    l1, l2, u1, u2, units_full = corr

    # Prefill normally runs flash attention, whose nested kv-chunk
    # while-loops are ALSO cost-counted once; the minis therefore lower
    # the materialized-softmax path (identical matmul FLOPs to the full
    # S x T flash rectangle) so the per-layer diff is complete. Bytes from
    # sdpa minis overstate flash's true footprint — mem_hlo is already
    # documented as a pre-fusion upper bound.
    mini_hooks = dict(hook_overrides or {})
    shape_obj = None
    from repro.configs import get_shape as _gs
    shape_obj = _gs(shape_name)
    if shape_obj.kind == "prefill":
        mini_hooks.setdefault("attn_impl", "sdpa")

    def mini(n_layers):
        # scan_layers=False: while-loop bodies are cost-counted ONCE by
        # HloCostAnalysis, so the minis must be UNROLLED for the 2-point
        # diff to see per-layer cost.
        c = dc.replace(cfg, num_layers=n_layers, scan_layers=False,
                       encoder_layers=min(cfg.encoder_layers, 1))
        r = dryrun.run_cell(arch, shape_name, multi_pod=multi_pod,
                            save=False, hook_overrides=mini_hooks,
                            cfg_override=c, tag="mini")
        return r

    def mini_enc(n_enc):
        c = dc.replace(cfg, num_layers=l1, scan_layers=False,
                       encoder_layers=n_enc)
        return dryrun.run_cell(arch, shape_name, multi_pod=multi_pod,
                               save=False, hook_overrides=mini_hooks,
                               cfg_override=c, tag="mini")

    r1, r2 = mini(l1), mini(l2)
    r_enc = mini_enc(2) if cfg.encoder_layers > 1 else None
    if r1["status"] == "ok" and r2["status"] == "ok" and \
            (r_enc is None or r_enc["status"] == "ok"):
        def extrapolate(key):
            def per_unit(a, b):
                return (b - a) / (u2 - u1)

            def enc_delta(a_val, e_val):
                # encoder diff: (enc=2) - (enc=1) at fixed decoder L1
                return (e_val - a_val) * (cfg.encoder_layers - 1) \
                    if r_enc is not None else 0.0

            if not isinstance(r1[key], dict):
                base = r1[key] + (units_full - u1) * per_unit(r1[key],
                                                              r2[key])
                return base + enc_delta(r1[key],
                                        r_enc[key] if r_enc else 0.0)
            out = {}
            for k in r1[key]:
                base = r1[key][k] + (units_full - u1) * per_unit(
                    r1[key][k], r2[key][k])
                out[k] = base + enc_delta(r1[key][k],
                                          r_enc[key][k] if r_enc else 0.0)
            return out

        full["flops_corrected"] = extrapolate("flops")
        full["bytes_corrected"] = extrapolate("bytes_accessed")
        full["collective_bytes_corrected"] = extrapolate("collective_bytes")
    else:
        full["correction_error"] = r1.get("error") or r2.get("error") or \
            (r_enc or {}).get("error")
    _write(full)
    return full


def _write(result: Dict[str, Any]) -> None:
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    tag = ("_" + result["tag"]) if result.get("tag") else ""
    name = (f"roofline_{result['arch']}_{result['shape']}_"
            f"{result['mesh']}{tag}.json")
    with open(os.path.join(ARTIFACT_DIR, name), "w") as f:
        json.dump(result, f, indent=1)


def model_flops(cfg, shape) -> float:
    """Analytic useful FLOPs for the cell (global, per step)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        base = 6.0 * n_active * shape.tokens
        attn = _attn_flops(cfg, shape.seq_len, shape.tokens, train=True)
    elif shape.kind == "prefill":
        base = 2.0 * n_active * shape.tokens
        attn = _attn_flops(cfg, shape.seq_len, shape.tokens, train=False)
    else:  # decode: one token per sequence
        toks = shape.global_batch
        base = 2.0 * n_active * toks
        attn = _decode_attn_flops(cfg, shape.seq_len, toks)
    return base + attn


def _attn_flops(cfg, seq, tokens, *, train: bool) -> float:
    """Causal QK^T + PV matmul FLOPs (0.5 triangle), fwd(+bwd)."""
    if cfg.attention == "none":
        return 0.0
    hd = cfg.resolved_head_dim
    if cfg.attention == "mla":
        hd = cfg.nope_head_dim + cfg.rope_head_dim
    heads = cfg.num_heads
    layers = cfg.num_layers if cfg.family != "hybrid" \
        else cfg.num_layers // max(cfg.shared_attn_every, 1)
    per_tok = 2.0 * 2.0 * heads * hd * (seq / 2.0)
    mult = 3.0 if train else 1.0   # bwd of the two matmuls ~ 2x fwd
    return per_tok * tokens * layers * mult


def _decode_attn_flops(cfg, cache_len, toks) -> float:
    if cfg.attention == "none":
        return 0.0
    hd = cfg.resolved_head_dim
    if cfg.attention == "mla":
        hd = cfg.kv_lora_rank + cfg.rope_head_dim  # absorbed decode
    heads = cfg.num_heads
    layers = cfg.num_layers if cfg.family != "hybrid" \
        else cfg.num_layers // max(cfg.shared_attn_every, 1)
    return 2.0 * 2.0 * heads * hd * cache_len * toks * layers


def memory_floor_bytes(cfg, shape, chips: int) -> float:
    """Analytic per-device HBM-traffic floor: weights touched fwd+bwd+opt,
    caches read/written, token activations once. HLO bytes_accessed counts
    every op pre-fusion, so it OVERSTATES traffic; the truth lies between
    this floor and the HLO number."""
    n = cfg.param_count()
    per_dev = n / chips
    if shape.kind == "train":
        # bf16 weights read twice (fwd+bwd) + grads written + opt state
        # (m, v fp32) read+write + fp32 master update.
        w = per_dev * (2 * 2 + 2 + 4 * 2 * 2 + 4 * 2)
        acts = shape.tokens / chips * cfg.d_model * 2 * 4
        return w + acts
    if shape.kind == "prefill":
        w = per_dev * 2
        acts = shape.tokens / chips * cfg.d_model * 2 * 4
        return w + acts
    # decode: weights (active for MoE) + full cache read per token
    active = cfg.active_param_count() / chips
    hd = cfg.resolved_head_dim
    if cfg.attention == "mla":
        cache_row = cfg.kv_lora_rank + cfg.rope_head_dim
    elif cfg.attention == "none":
        cache_row = 0
    else:
        cache_row = 2 * cfg.num_kv_heads * hd
    layers = cfg.num_layers if cfg.family != "hybrid" \
        else cfg.num_layers // max(cfg.shared_attn_every, 1)
    cache = shape.global_batch * shape.seq_len * cache_row * 2 * layers \
        / chips
    return active * 2 + cache


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    memory_floor_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    useful_ratio: float

    def row(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def analyze(artifact: Dict[str, Any], chips: int) -> Roofline:
    from repro.configs import get_config, get_shape

    cfg = get_config(artifact["arch"])
    shape = get_shape(artifact["shape"])
    # artifacts store PER-DEVICE HLO numbers (SPMD module); roofline terms
    # are per-device time, which is the step time at perfect overlap = 0.
    flops = artifact.get("flops_corrected", artifact["flops"])
    bts = artifact.get("bytes_corrected", artifact["bytes_accessed"])
    coll = artifact.get("collective_bytes_corrected",
                        artifact["collective_bytes"])
    coll_total = sum(v for k, v in coll.items() if k != "counts")
    compute_s = flops / PEAK_FLOPS
    memory_s = bts / HBM_BW
    floor_s = memory_floor_bytes(cfg, shape, chips) / HBM_BW
    collective_s = coll_total / ICI_BW
    # bottleneck judged on the FLOOR memory estimate (HLO bytes are a
    # pre-fusion upper bound; see module docstring).
    terms = {"compute": compute_s, "memory": floor_s,
             "collective": collective_s}
    mf = model_flops(cfg, shape)
    return Roofline(
        compute_s=compute_s, memory_s=memory_s, memory_floor_s=floor_s,
        collective_s=collective_s,
        bottleneck=max(terms, key=terms.get),
        model_flops=mf,
        useful_ratio=mf / (flops * chips) if flops > 0 else 0.0)


def sweep(multi_pod: bool = False) -> None:
    """Corrected-roofline pass over every applicable cell (single-pod by
    default, per the assignment: the roofline table is single-pod)."""
    from repro.configs import ARCH_IDS, get_config, get_shape
    from repro.configs.base import SHAPES, shape_applicable

    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for s in SHAPES:
            ok, why = shape_applicable(cfg, s)
            if not ok:
                print(f"[skip] {arch} {s.name}: {why}", flush=True)
                continue
            r = corrected_cell(arch, s.name, multi_pod=multi_pod)
            print(f"[{r['status']}] {arch} {s.name} "
                  f"flops={r.get('flops_corrected', r.get('flops'))}",
                  flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--glob", default="roofline_*.json")
    ap.add_argument("--sweep", action="store_true")
    args = ap.parse_args()
    if args.sweep:
        sweep()
        return
    import glob as g
    rows = []
    for path in sorted(g.glob(os.path.join(ARTIFACT_DIR, args.glob))):
        art = json.load(open(path))
        # baseline table: skip tagged variants and preserved _prev copies
        if art.get("tag") or "_prev" in os.path.basename(path):
            continue
        if art.get("status") != "ok":
            rows.append((art, None))
            continue
        chips = 512 if art["mesh"] == "pod2x16x16" else 256
        rows.append((art, analyze(art, chips)))
    hdr = (f"{'arch':27s}{'shape':13s}{'mesh':11s}{'compute_s':>11s}"
           f"{'mem_hlo_s':>11s}{'mem_floor':>10s}{'coll_s':>9s}"
           f"{'bound':>8s}{'useful':>8s}")
    print(hdr)
    for art, r in rows:
        if r is None:
            print(f"{art['arch']:27s}{art['shape']:13s}{art['mesh']:11s}"
                  f"  [{art['status']}] {art.get('reason', '')[:40]}")
            continue
        print(f"{art['arch']:27s}{art['shape']:13s}{art['mesh']:11s}"
              f"{r.compute_s:>11.4f}{r.memory_s:>11.4f}"
              f"{r.memory_floor_s:>10.4f}"
              f"{r.collective_s:>9.4f}{r.bottleneck:>8s}"
              f"{r.useful_ratio:>8.2f}")


if __name__ == "__main__":
    main()
