"""Procedural synthetic scenes (offline stand-in for Synthetic-NeRF / T&T).

Two generators with controllable statistics:

- ``random_blob_scene``  : isotropic-ish Gaussians in a box — quick tests.
- ``structured_scene``   : an "indoor-like" room (large flat wall/floor
  Gaussians = low-frequency regions) plus dense high-frequency clutter
  clusters. This reproduces the *workload-imbalance* statistics the paper
  exploits (Fig. 5: per-tile Gaussian counts spanning >1 order of
  magnitude) and the indoor/outdoor contrast discussed in Sec. VI.

``clutter`` in [0, 1] moves the scene from indoor-like (flat, view
consistent) to outdoor-like (many small high-frequency Gaussians).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.gaussians import GaussianScene, rgb_to_sh_dc


def random_blob_scene(key: jax.Array, n: int, *, sh_degree: int = 0,
                      extent: float = 3.0, scale_range=(-3.5, -1.5),
                      depth_offset: float = 6.0) -> GaussianScene:
    """n Gaussians uniform in a box centered ``depth_offset`` ahead of origin."""
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    means = jax.random.uniform(k1, (n, 3), minval=-extent, maxval=extent)
    means = means.at[:, 2].add(depth_offset)
    log_scales = jax.random.uniform(k2, (n, 3), minval=scale_range[0],
                                    maxval=scale_range[1])
    quats = jax.random.normal(k3, (n, 4))
    opacity_logits = jax.random.uniform(k4, (n,), minval=-1.0, maxval=3.0)
    k_sh = (sh_degree + 1) ** 2
    base = jax.random.uniform(k5, (n, 3), minval=0.0, maxval=1.0)
    sh = jnp.zeros((n, k_sh, 3)).at[:, 0, :].set(rgb_to_sh_dc(base))
    if k_sh > 1:
        sh = sh.at[:, 1:, :].set(
            0.1 * jax.random.normal(jax.random.fold_in(k5, 1), (n, k_sh - 1, 3)))
    return GaussianScene(means, log_scales, quats, opacity_logits, sh)


def structured_scene(key: jax.Array, n: int, *, sh_degree: int = 1,
                     clutter: float = 0.5, room: float = 4.0) -> GaussianScene:
    """Room-like scene: walls/floor (few, large, flat) + clutter clusters."""
    n_flat = max(int(n * (1.0 - clutter) * 0.4), 16)
    n_clutter = n - n_flat
    kf, kc, kq, ko, ks, kcl = jax.random.split(key, 6)

    # --- flat structure: Gaussians pancaked onto 5 box faces -------------
    face = jax.random.randint(kf, (n_flat,), 0, 5)
    uv = jax.random.uniform(jax.random.fold_in(kf, 1), (n_flat, 2),
                            minval=-room, maxval=room)
    # faces: 0 floor(y=+room), 1 back(z=2*room), 2 left(x=-room),
    #        3 right(x=+room), 4 ceil(y=-room)
    fx = jnp.select([face == 2, face == 3], [-room, room], uv[:, 0])
    fy = jnp.select([face == 0, face == 4], [room, -room], uv[:, 1])
    fz = jnp.where(face == 1, 2 * room, room + uv[:, 0] * 0.0 +
                   jax.random.uniform(jax.random.fold_in(kf, 2), (n_flat,),
                                      minval=0.0, maxval=room))
    flat_means = jnp.stack([fx, fy, fz], -1)
    # pancake: large in-plane scale, tiny normal scale
    flat_scales = jnp.full((n_flat, 3), -0.8)
    flat_scales = jnp.where(
        jnp.stack([face == 2, face == 0, face == 1], -1)
        | jnp.stack([face == 3, face == 4, face == 1], -1),
        -4.0, flat_scales)

    # --- clutter: gaussian clusters of small splats ----------------------
    n_clusters = 12
    centers = jax.random.uniform(kcl, (n_clusters, 3), minval=-0.7 * room,
                                 maxval=0.7 * room)
    centers = centers.at[:, 2].add(1.2 * room)
    assign = jax.random.randint(jax.random.fold_in(kcl, 1), (n_clutter,), 0,
                                n_clusters)
    jitter = jax.random.normal(kc, (n_clutter, 3)) * (0.15 * room)
    clutter_means = centers[assign] + jitter
    clutter_scales = jax.random.uniform(
        jax.random.fold_in(ks, 1), (n_clutter, 3), minval=-4.5, maxval=-2.5)

    means = jnp.concatenate([flat_means, clutter_means], 0)
    log_scales = jnp.concatenate([flat_scales, clutter_scales], 0)
    quats = jax.random.normal(kq, (n, 4))
    opacity_logits = jnp.concatenate([
        jnp.full((n_flat,), 2.5),                      # walls: near-opaque
        jax.random.uniform(ko, (n_clutter,), minval=-1.0, maxval=2.5)])

    k_sh = (sh_degree + 1) ** 2
    kb1, kb2 = jax.random.split(jax.random.fold_in(ko, 7))
    flat_rgb = jnp.tile(jax.random.uniform(kb1, (1, 3), minval=0.4,
                                           maxval=0.8), (n_flat, 1))
    flat_rgb = flat_rgb + 0.05 * jax.random.normal(jax.random.fold_in(kb1, 1),
                                                   (n_flat, 3))
    clutter_rgb = jax.random.uniform(kb2, (n_clutter, 3))
    rgbs = jnp.clip(jnp.concatenate([flat_rgb, clutter_rgb], 0), 0.05, 0.95)
    sh = jnp.zeros((n, k_sh, 3)).at[:, 0, :].set(rgb_to_sh_dc(rgbs))
    if k_sh > 1:
        sh = sh.at[:, 1:, :].set(
            0.08 * jax.random.normal(jax.random.fold_in(kb2, 2),
                                     (n, k_sh - 1, 3)))
    return GaussianScene(means, log_scales, quats, opacity_logits, sh)
