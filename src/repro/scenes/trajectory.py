"""Continuous camera trajectories simulating the paper's 90 FPS setup.

Paper Sec. VI-A: "camera motion at 1.8 m/s and a rotational speed of 90
degrees per second" rendered at 90 FPS -> per-frame deltas of 2 cm
translation and 1 degree rotation. ``orbit_trajectory`` and
``dolly_trajectory`` generate pose sequences with exactly those deltas.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.camera import look_at

FPS = 90.0
SPEED_M_S = 1.8
ROT_DEG_S = 90.0


def orbit_trajectory(n_frames: int, *, radius: float = 6.0,
                     target=(0.0, 0.0, 6.0), height: float = -0.5,
                     fps: float = FPS, rot_deg_s: float = ROT_DEG_S):
    """Orbit around ``target`` at the paper's angular speed. (F, 4, 4)."""
    d_theta = np.radians(rot_deg_s / fps)
    thetas = np.arange(n_frames) * d_theta
    target = jnp.asarray(target, jnp.float32)
    poses = []
    for th in thetas:
        eye = target + radius * jnp.asarray(
            [np.sin(th), 0.0, -np.cos(th)], jnp.float32)
        eye = eye.at[1].add(height)
        poses.append(look_at(eye, target))
    return jnp.stack(poses)


def dolly_trajectory(n_frames: int, *, start=(0.0, -0.3, 0.0),
                     target=(0.0, 0.0, 8.0), fps: float = FPS,
                     speed: float = SPEED_M_S, lateral: float = 0.35):
    """Forward dolly with gentle lateral sway — a corridor walkthrough."""
    step = speed / fps
    start = jnp.asarray(start, jnp.float32)
    target = jnp.asarray(target, jnp.float32)
    fwd = target - start
    fwd = fwd / jnp.linalg.norm(fwd)
    poses = []
    for i in range(n_frames):
        sway = lateral * np.sin(2.0 * np.pi * i / 180.0)
        eye = start + fwd * (step * i) + jnp.asarray([sway, 0.0, 0.0])
        poses.append(look_at(eye, target))
    return jnp.stack(poses)
