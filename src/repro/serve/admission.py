"""Admission control for the multi-bucket serve loop: who renders when.

The server's ragged mixed-bucket rounds (server.py, DESIGN.md §11) can
dispatch one executable per scene-bucket group in a single round — but
*which* groups run, and in what order, is a policy question, and the
naive answer ("drain the in-flight bucket first") is exactly the
fleet-level stall the paper warns about: a minority-bucket stream stuck
behind a busy majority bucket waits unboundedly. This module owns that
policy:

- **Round planning** (``plan_round``): given per-bucket demand, return
  the ordered list of scene buckets this round serves. ``mode="mixed"``
  (default) serves every bucket with pending work, ordered by SLO
  weight x rounds waited; ``max_groups_per_round`` caps the list (a
  device-budget knob), and **aging** guarantees the cap never starves:
  a bucket that would exceed its ``max_wait_rounds`` if skipped again
  jumps the queue. ``mode="drain"`` reproduces the legacy
  drain-before-switch loop — kept so benchmarks/serve_bench.py can
  demonstrate the starvation it causes (the before/after replay).
- **Backpressure** (``offer``): with ``max_waiting`` set, the waiting
  set is bounded — ``offer`` returns False when full and the caller
  must defer or reject the stream (``StreamServer.attach`` raises
  ``AdmissionRejected``; ``try_attach``/``run`` defer and retry).
- **SLO classes** (``SLOClass``): per-stream service classes. ``weight``
  biases both the elastic-B resize (a heavy class inflates its bucket's
  effective queue depth, snapping B up sooner) and group ordering;
  ``max_wait_rounds`` tightens the aging bound for buckets with that
  class waiting (an interactive bucket ages out of the queue faster
  than bulk).
- **Fairness accounting** (``report``): per-bucket demand/served round
  counts, lifetime max wait, service share, and a Jain fairness index
  over the shares — the numbers serve_bench.json publishes.

Wait-clock semantics: a bucket's wait counts *consecutive rounds it had
pending work but was not served*; serving it (or its queue emptying)
resets the clock. ``max_wait.get(bucket)`` is the lifetime maximum —
the starvation regression test pins it ≤ ``max_wait_rounds``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Hashable, List, Optional, Sequence, Tuple

from repro.obs.metrics import MetricsRegistry

__all__ = [
    "AdmissionConfig", "AdmissionController", "AdmissionRejected",
    "BucketDemand", "SLOClass", "DEFAULT_SLO_CLASSES", "jain_index",
]


class AdmissionRejected(RuntimeError):
    """Backpressure: the waiting set is full; defer or drop the stream."""


def jain_index(xs: Sequence[float]) -> float:
    """Jain's fairness index over non-negative allocations:
    ``(sum x)^2 / (n * sum x^2)``. 1.0 = perfectly fair (all equal),
    1/n = maximally unfair (one allocation gets everything). Empty or
    all-zero input reads as fair (nothing is being divided)."""
    xs = [float(x) for x in xs]
    if not xs:
        return 1.0
    total = sum(xs)
    sq = sum(x * x for x in xs)
    if sq == 0.0:
        return 1.0
    return (total * total) / (len(xs) * sq)


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """A per-stream service class (see module docstring).

    ``weight`` >= 1 biases scheduling toward the class (group ordering
    and effective queue depth for the elastic-B resize); weights < 1
    de-prioritize ordering but never shrink a bucket's effective depth
    below its true depth (bulk streams must not slow their own bucket's
    batch below what the queue needs). ``max_wait_rounds`` (optional)
    tightens the aging bound for buckets where the class is waiting.
    """

    name: str
    weight: float = 1.0
    max_wait_rounds: Optional[int] = None

    def __post_init__(self):
        if self.weight <= 0:
            raise ValueError(f"SLO weight must be > 0, got {self.weight}")
        if self.max_wait_rounds is not None and self.max_wait_rounds < 1:
            raise ValueError(f"SLO max_wait_rounds must be >= 1, got "
                             f"{self.max_wait_rounds}")


STANDARD_SLO = SLOClass("standard", weight=1.0)
INTERACTIVE_SLO = SLOClass("interactive", weight=4.0, max_wait_rounds=1)
BULK_SLO = SLOClass("bulk", weight=0.25)
DEFAULT_SLO_CLASSES = (STANDARD_SLO, INTERACTIVE_SLO, BULK_SLO)


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Knobs for the round planner + backpressure (module docstring)."""

    max_wait_rounds: int = 4            # aging bound (rounds)
    max_waiting: Optional[int] = None   # backpressure: waiting-set bound
    max_groups_per_round: Optional[int] = None  # None: all buckets w/ work
    mode: str = "mixed"                 # "mixed" | "drain" (legacy)
    slo_classes: Tuple[SLOClass, ...] = DEFAULT_SLO_CLASSES

    def __post_init__(self):
        if self.mode not in ("mixed", "drain"):
            raise ValueError(f"mode must be 'mixed' or 'drain', got "
                             f"{self.mode!r}")
        if self.max_wait_rounds < 1:
            raise ValueError(f"max_wait_rounds must be >= 1, got "
                             f"{self.max_wait_rounds}")
        if self.max_waiting is not None and self.max_waiting < 1:
            raise ValueError(f"max_waiting must be >= 1, got "
                             f"{self.max_waiting}")
        if self.max_groups_per_round is not None \
                and self.max_groups_per_round < 1:
            raise ValueError(f"max_groups_per_round must be >= 1, got "
                             f"{self.max_groups_per_round}")
        names = [c.name for c in self.slo_classes]
        if len(names) != len(set(names)) or not names:
            raise ValueError(f"slo_classes need unique names, got {names}")

    def slo(self, name: Optional[str]) -> SLOClass:
        """Class by name; None -> the first (default) class."""
        if name is None:
            return self.slo_classes[0]
        for c in self.slo_classes:
            if c.name == name:
                return c
        raise KeyError(f"unknown SLO class {name!r}; known: "
                       f"{[c.name for c in self.slo_classes]}")


@dataclasses.dataclass
class BucketDemand:
    """One scene bucket's demand snapshot for ``plan_round``.

    ``depth`` counts streams wanting service (bound to a slot, or
    waiting with pending poses); ``pending`` counts streams with poses
    actually queued (what a round could render); ``bound`` counts slots
    currently occupied (the drain mode's in-flight signal). ``weight``
    is the max SLO weight among wanting streams, ``weighted_depth`` the
    SLO-inflated depth the elastic-B resize uses, and ``wait_bound``
    the tightest per-class ``max_wait_rounds`` among waiting streams
    (None: use the config bound). ``order`` is the smallest session id
    wanting service — the oldest-first tiebreak.
    """

    depth: int = 0
    pending: int = 0
    bound: int = 0
    weight: float = 1.0
    weighted_depth: float = 0.0
    wait_bound: Optional[int] = None
    order: float = math.inf


class AdmissionController:
    """Round planning + backpressure + fairness accounting.

    ``metrics`` (optional) is the serve stack's shared
    ``MetricsRegistry`` (repro/obs/metrics.py): the controller publishes
    its backpressure counter and per-bucket wait gauges there so one
    ``snapshot()`` covers admission next to the server's own metrics.
    A standalone controller gets a private registry — no None checks.
    """

    def __init__(self, cfg: AdmissionConfig = AdmissionConfig(),
                 metrics: Optional[MetricsRegistry] = None):
        self.cfg = cfg
        self.metrics = MetricsRegistry() if metrics is None else metrics
        self._m_deferred = self.metrics.counter(
            "serve_deferrals_total",
            "offer() refusals: arrivals deferred by backpressure")
        # Consecutive rounds each bucket had pending work but was not
        # served (the aging clock), and the lifetime max of that clock.
        self._wait: Dict[Hashable, int] = {}
        self.max_wait: Dict[Hashable, int] = {}
        self.demand_rounds: Dict[Hashable, int] = {}
        self.served_rounds: Dict[Hashable, int] = {}
        self.frames_served: Dict[Hashable, int] = {}

    @property
    def deferred(self) -> int:
        """Lifetime offer() refusals (backpressure events)."""
        return int(self._m_deferred.value)

    # -- backpressure --------------------------------------------------------
    def offer(self, waiting_now: int) -> bool:
        """May one more stream join the waiting set? False = defer/reject
        (counted — a deferred arrival retried next round counts again)."""
        if self.cfg.max_waiting is not None \
                and waiting_now >= self.cfg.max_waiting:
            self._m_deferred.inc()
            return False
        return True

    # -- round planning ------------------------------------------------------
    def wait_of(self, bucket: Hashable) -> int:
        return self._wait.get(bucket, 0)

    def _effective_bound(self, d: BucketDemand) -> int:
        if d.wait_bound is None:
            return self.cfg.max_wait_rounds
        return min(self.cfg.max_wait_rounds, d.wait_bound)

    def plan_round(self, demand: Dict[Hashable, BucketDemand]
                   ) -> List[Hashable]:
        """The ordered scene buckets this round serves.

        ``demand`` iteration order is the server's bucket discovery
        order (stable across rounds for stable session sets).
        """
        if self.cfg.mode == "drain":
            # Legacy drain-before-switch: the in-flight bucket while any
            # slot is bound, else the oldest waiting bucket. No aging —
            # this is the starvation baseline the replay demonstrates.
            for b, d in demand.items():
                if d.bound > 0:
                    return [b]
            cand = [b for b, d in demand.items() if d.pending > 0]
            if not cand:
                return []
            return [min(cand, key=lambda b: demand[b].order)]

        cand = [b for b, d in demand.items() if d.pending > 0]
        # Aged buckets first (skipping one would break the wait bound),
        # then by SLO-weighted wait, oldest stream as the tiebreak.
        def key(b):
            d = demand[b]
            w = self._wait.get(b, 0)
            aged = (w + 1) >= self._effective_bound(d)
            return (not aged, -(w + 1) * d.weight, d.order)
        cand.sort(key=key)
        cap = self.cfg.max_groups_per_round
        return cand if cap is None else cand[:cap]

    def note_round(self, demand: Dict[Hashable, BucketDemand],
                   served: Sequence[Hashable]) -> None:
        """Advance the wait clocks after a round: buckets with pending
        work that went unserved age by one; served (or emptied) buckets
        reset."""
        served = set(served)
        for b, d in demand.items():
            if d.pending <= 0:
                self._wait[b] = 0
                continue
            self.demand_rounds[b] = self.demand_rounds.get(b, 0) + 1
            if b in served:
                self.served_rounds[b] = self.served_rounds.get(b, 0) + 1
                self._wait[b] = 0
            else:
                w = self._wait.get(b, 0) + 1
                self._wait[b] = w
                self.max_wait[b] = max(self.max_wait.get(b, 0), w)
                self.metrics.gauge(
                    "serve_bucket_max_wait_rounds",
                    "lifetime max consecutive unserved rounds with "
                    "pending work", bucket=str(b)).set_max(w)

    def record_service(self, bucket: Hashable, frames: int) -> None:
        self.frames_served[bucket] = \
            self.frames_served.get(bucket, 0) + int(frames)

    # -- fairness ------------------------------------------------------------
    def shares(self) -> Dict[Hashable, float]:
        """Per-bucket service share: served rounds / rounds with demand."""
        return {b: (self.served_rounds.get(b, 0) / n if n else 1.0)
                for b, n in self.demand_rounds.items()}

    def report(self) -> dict:
        shares = self.shares()
        return {
            "mode": self.cfg.mode,
            "max_wait_rounds_config": self.cfg.max_wait_rounds,
            "jain_service": round(jain_index(list(shares.values())), 4),
            "max_wait_rounds": max(self.max_wait.values(), default=0),
            "deferred": self.deferred,
            "per_bucket": {
                str(b): {
                    "demand_rounds": self.demand_rounds.get(b, 0),
                    "served_rounds": self.served_rounds.get(b, 0),
                    "frames": self.frames_served.get(b, 0),
                    "max_wait_rounds": self.max_wait.get(b, 0),
                    "share": round(shares.get(b, 1.0), 4),
                } for b in self.demand_rounds},
        }
