"""Streaming serving subsystem (DESIGN.md §8, §10).

Turns the batch engine (core/engine.py) into a multi-scene server for
churning streams: a scene registry pads scenes to bucketed Gaussian
counts so same-bucket scenes share executables (``scenes``), sessions
attach/detach against a scene with phase-staggered key-frame schedules
(``session``), a scene-aware continuous batcher packs same-scene
streams into contiguous slot groups of an *elastic* B-slot batch over
``engine.render_streams`` (``batcher``), a bucketed executable cache
bounds recompilation while a 2-axis ``(B, R)`` policy picks the batch
size from queue depth and ``rerender_capacity`` from recorded demand
(``cache``), stream slots — and their ``slot_scene`` gather indices —
shard across devices (``placement``), an admission controller plans
each round's scene-bucket groups with aging, backpressure, and SLO
classes (``admission``), and ``server`` ties it into ragged
mixed-bucket serving rounds (DESIGN.md §11) with latency / throughput /
utilization / per-bucket fairness metrics plus optional
accelerator-in-the-loop simulated latencies.
"""
from repro.serve.admission import (AdmissionConfig, AdmissionController,
                                   AdmissionRejected, BucketDemand,
                                   DEFAULT_SLO_CLASSES, SLOClass, jain_index)
from repro.serve.batcher import ContinuousBatcher, SlotBatch
from repro.serve.cache import (BucketPolicy, ExecutableCache, pick_capacity,
                               snap_capacity, suggest_buckets,
                               suggest_capacity, validate_buckets)
from repro.serve.placement import build_render_fn, stream_mesh
from repro.serve.scenes import (SceneEntry, SceneRegistry, pad_scene,
                                snap_scene_bucket)
from repro.serve.server import (PoissonTraffic, ReplayTraffic, ServeConfig,
                                StreamServer, TrafficConfig, burst_trace,
                                skewed_trace)
from repro.serve.session import SessionManager, StreamSession

__all__ = [
    "AdmissionConfig", "AdmissionController", "AdmissionRejected",
    "BucketDemand", "BucketPolicy", "ContinuousBatcher",
    "DEFAULT_SLO_CLASSES", "ExecutableCache", "PoissonTraffic",
    "ReplayTraffic", "SLOClass", "SceneEntry", "SceneRegistry",
    "ServeConfig", "SessionManager", "SlotBatch", "StreamServer",
    "StreamSession", "TrafficConfig", "build_render_fn", "burst_trace",
    "jain_index", "pad_scene", "pick_capacity", "skewed_trace",
    "snap_capacity", "snap_scene_bucket", "stream_mesh", "suggest_buckets",
    "suggest_capacity", "validate_buckets",
]
