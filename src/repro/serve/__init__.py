"""Streaming serving subsystem (DESIGN.md §8, §10).

Turns the batch engine (core/engine.py) into a multi-scene server for
churning streams: a scene registry pads scenes to bucketed Gaussian
counts so same-bucket scenes share executables (``scenes``), sessions
attach/detach against a scene with phase-staggered key-frame schedules
(``session``), a scene-aware continuous batcher packs same-scene
streams into contiguous slot groups of an *elastic* B-slot batch over
``engine.render_streams`` (``batcher``), a bucketed executable cache
bounds recompilation while a 2-axis ``(B, R)`` policy picks the batch
size from queue depth and ``rerender_capacity`` from recorded demand
(``cache``), stream slots — and their ``slot_scene`` gather indices —
shard across devices (``placement``), and ``server`` ties it into the
serve loop with latency / throughput / utilization metrics plus
optional accelerator-in-the-loop simulated latencies.
"""
from repro.serve.batcher import ContinuousBatcher, SlotBatch
from repro.serve.cache import (BucketPolicy, ExecutableCache, pick_capacity,
                               snap_capacity, suggest_buckets,
                               suggest_capacity, validate_buckets)
from repro.serve.placement import build_render_fn, stream_mesh
from repro.serve.scenes import (SceneEntry, SceneRegistry, pad_scene,
                                snap_scene_bucket)
from repro.serve.server import (PoissonTraffic, ServeConfig, StreamServer,
                                TrafficConfig)
from repro.serve.session import SessionManager, StreamSession

__all__ = [
    "BucketPolicy", "ContinuousBatcher", "ExecutableCache",
    "PoissonTraffic", "SceneEntry", "SceneRegistry", "ServeConfig",
    "SessionManager", "SlotBatch", "StreamServer", "StreamSession",
    "TrafficConfig", "build_render_fn", "pad_scene", "pick_capacity",
    "snap_capacity", "snap_scene_bucket", "stream_mesh", "suggest_buckets",
    "suggest_capacity", "validate_buckets",
]
