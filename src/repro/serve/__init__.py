"""Streaming serving subsystem (DESIGN.md §8).

Turns the batch engine (core/engine.py) into a server for churning
streams: sessions attach/detach with phase-staggered key-frame schedules
(``session``), a continuous batcher packs active sessions into fixed
B-slot batches over ``engine.render_streams`` (``batcher``), a bucketed
executable cache bounds recompilation while a workload-predictive policy
picks ``rerender_capacity`` (``cache``), stream slots shard across
devices (``placement``), and ``server`` ties it into the serve loop with
latency / throughput / utilization metrics.
"""
from repro.serve.batcher import ContinuousBatcher, SlotBatch
from repro.serve.cache import (ExecutableCache, pick_capacity,
                               snap_capacity, suggest_capacity,
                               validate_buckets)
from repro.serve.placement import build_render_fn, stream_mesh
from repro.serve.server import (PoissonTraffic, ServeConfig, StreamServer,
                                TrafficConfig)
from repro.serve.session import SessionManager, StreamSession

__all__ = [
    "ContinuousBatcher", "ExecutableCache", "PoissonTraffic",
    "ServeConfig", "SessionManager", "SlotBatch", "StreamServer",
    "StreamSession", "TrafficConfig", "build_render_fn", "pick_capacity",
    "snap_capacity", "stream_mesh", "suggest_capacity",
    "validate_buckets",
]
