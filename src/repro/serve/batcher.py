"""Continuous batching: churning sessions -> fixed (B, F) engine batches.

The engine compiles one executable per (B, F, cfg) shape, so the batcher
never changes shape as streams come and go. It keeps B slots; each round
it binds waiting sessions to free slots, pops up to ``chunk`` pending
poses per bound session into a dense (B, chunk, 4, 4) batch, and masks
everything else: a slot with fewer pending poses gets a shorter
``count`` (the engine freezes its carry past the count — the key-frame
schedule resumes exactly where it paused), and an unbound slot rides
along with ``count=0`` and a throwaway fresh carry. The engine's masking
guarantees padded slots/frames contribute nothing and active streams
render bit-identically to a solo ``render_trajectory`` — pinned by
tests/test_serve.py.

``build`` pops poses (and their enqueue stamps) out of the sessions;
``commit`` writes back the final carries, stamps per-frame latencies,
and releases slots of drained-and-closed sessions (detaching them from
the manager).
"""
from __future__ import annotations

from typing import List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.core.camera import Camera
from repro.core.engine import EngineCarry, StreamsResult
from repro.serve.session import SessionManager

_EYE = np.eye(4, dtype=np.float32)


class SlotBatch(NamedTuple):
    """One round's dense engine input plus the host-side bookkeeping."""

    poses: jax.Array        # (B, F, 4, 4)
    counts: jax.Array       # (B,) int32 active-frame counts
    phases: jax.Array       # (B,) int32 per-slot key-frame phases
    carries: EngineCarry    # stacked (B, ...) resume carries
    sids: Tuple[Optional[int], ...]          # slot -> session id (or None)
    enq_times: Tuple[Tuple[float, ...], ...]  # per-slot popped stamps

    @property
    def active_frames(self) -> int:
        return int(np.asarray(self.counts).sum())


class ContinuousBatcher:
    """Fixed B-slot batcher over ``engine.render_streams`` (see module)."""

    def __init__(self, slots: int, chunk: int, cam: Camera):
        if slots < 1 or chunk < 1:
            raise ValueError(f"need slots >= 1 and chunk >= 1, got "
                             f"{slots}, {chunk}")
        self.slots = int(slots)
        self.chunk = int(chunk)
        self.cam = cam
        self._slot_sid: List[Optional[int]] = [None] * self.slots
        # Idle slots are all identical (count 0, eye pose, zero state) —
        # one shared template instead of fresh device zeros every round.
        self._idle_carry = engine.init_carry(cam, _EYE)

    @property
    def bound(self) -> int:
        return sum(s is not None for s in self._slot_sid)

    def admit(self, manager: SessionManager) -> int:
        """Bind waiting sessions (oldest first) to free slots."""
        admitted = 0
        waiting = manager.waiting()
        for i in range(self.slots):
            if self._slot_sid[i] is not None or not waiting:
                continue
            sess = waiting.pop(0)
            sess.slot = i
            self._slot_sid[i] = sess.sid
            admitted += 1
        return admitted

    def empty_batch(self) -> SlotBatch:
        """An all-idle (count-0) batch that touches no session state —
        shape-identical to a real round, so it drives executable warmup
        without popping poses from bound sessions."""
        b, f = self.slots, self.chunk
        carries = [self._idle_carry] * b
        stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *carries)
        return SlotBatch(poses=jnp.asarray(np.tile(_EYE, (b, f, 1, 1))),
                         counts=jnp.zeros((b,), jnp.int32),
                         phases=jnp.zeros((b,), jnp.int32), carries=stacked,
                         sids=(None,) * b, enq_times=((),) * b)

    def build(self, manager: SessionManager) -> SlotBatch:
        """Pop up to ``chunk`` poses per bound session into a dense batch."""
        b, f = self.slots, self.chunk
        poses = np.tile(_EYE, (b, f, 1, 1))
        counts = np.zeros((b,), np.int32)
        phases = np.zeros((b,), np.int32)
        carries: List[EngineCarry] = []
        sids: List[Optional[int]] = []
        stamps: List[Tuple[float, ...]] = []
        for i, sid in enumerate(self._slot_sid):
            sess = manager.sessions.get(sid) if sid is not None else None
            if sid is not None and sess is None:
                # Detached externally since the last round: free the slot
                # now (commit only handles cancellation mid-flight).
                self._slot_sid[i] = sid = None
            slot_stamps: List[float] = []
            if sess is not None:
                phases[i] = sess.phase
                k = 0
                while sess.pending and k < f:
                    pose, t_enq = sess.pending.popleft()
                    poses[i, k] = pose
                    slot_stamps.append(t_enq)
                    k += 1
                counts[i] = k
                if k:
                    # Pad the tail with the last real pose: masked frames
                    # still trace the render, so keep their inputs tame.
                    poses[i, k:] = poses[i, k - 1]
                if sess.carry is None:
                    sess.carry = engine.init_carry(self.cam, poses[i, 0])
                carries.append(sess.carry)
                sids.append(sid)
            else:
                carries.append(self._idle_carry)
                sids.append(None)
            stamps.append(tuple(slot_stamps))
        stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *carries)
        return SlotBatch(poses=jnp.asarray(poses),
                         counts=jnp.asarray(counts),
                         phases=jnp.asarray(phases), carries=stacked,
                         sids=tuple(sids), enq_times=tuple(stamps))

    def commit(self, batch: SlotBatch, result: StreamsResult,
               manager: SessionManager, now: float) -> List["StreamSession"]:
        """Write back carries/latencies; detach drained sessions.

        Returns the sessions detached this round (their slots free up for
        the next ``admit``; the server keeps them for final stats).
        """
        detached: List = []
        for i, sid in enumerate(batch.sids):
            if sid is None:
                continue
            if sid not in manager.sessions:
                # Cancelled externally (manager.detach) mid-flight: the
                # rendered chunk has no consumer, but the slot must not
                # leak.
                if self._slot_sid[i] == sid:
                    self._slot_sid[i] = None
                continue
            sess = manager.sessions[sid]
            sess.carry = jax.tree_util.tree_map(lambda a, i=i: a[i],
                                                result.carries)
            n = int(np.asarray(batch.counts)[i])
            sess.frames_rendered += n
            sess.latencies.extend(now - t for t in batch.enq_times[i][:n])
            if sess.done:
                manager.detach(sid)
                sess.slot = None
                self._slot_sid[i] = None
                detached.append(sess)
        return detached
