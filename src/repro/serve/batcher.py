"""Continuous batching: churning sessions -> fixed (B, F) engine batches.

The engine compiles one executable per (B, F, cfg) shape, so the batcher
never changes shape *within* a round as streams come and go. It keeps B
slots; each round it binds waiting sessions to free slots, pops up to
``chunk`` pending poses per bound session into a dense (B, chunk, 4, 4)
batch, and masks everything else: a slot with fewer pending poses gets a
shorter ``count`` (the engine freezes its carry past the count — the
key-frame schedule resumes exactly where it paused), and an unbound slot
rides along with ``count=0`` and a throwaway fresh carry. The engine's
masking guarantees padded slots/frames contribute nothing and active
streams render bit-identically to a solo ``render_trajectory`` — pinned
by tests/test_serve.py.

Two serving axes beyond the fixed-B original (DESIGN.md §10):

- **scene-aware packing.** Sessions carry a ``scene_id``; ``admit``
  packs same-scene streams into *contiguous slot groups* of ``group``
  slots (the server sets ``group`` to the per-device shard B/D, so
  ``placement.py`` lands whole scene groups on devices) and ``build``
  emits ``slot_scene`` — per-slot indices into the round's distinct
  ``scene_ids`` — for the engine's stacked-scene gather. Idle slots
  reuse local scene 0 (they are count-0 masked, the scene is only
  traced). ``admit``'s optional ``allowed`` set enforces the server's
  same-bucket-per-round rule.
- **elastic B.** ``resize`` grows/shrinks the slot count between rounds.
  Shrinking unbinds the sessions in the removed slots — their carries
  live on the session, so they rejoin the waiting queue and resume later
  bit-identically (the elastic-B carry rule, pinned by
  tests/test_serve_scenes.py).

``build`` pops poses (and their enqueue stamps) out of the sessions;
``commit`` writes back the final carries, stamps per-frame latencies,
optionally retains rendered frames on the session
(``collect_frames=True``), and releases slots of drained-and-closed
sessions (detaching them from the manager).
"""
from __future__ import annotations

from typing import List, NamedTuple, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.core.camera import Camera
from repro.core.engine import EngineCarry, StreamsResult
from repro.obs.trace import NULL_TRACER, Tracer
from repro.serve.session import SessionManager

_EYE = np.eye(4, dtype=np.float32)


class SlotBatch(NamedTuple):
    """One round's dense engine input plus the host-side bookkeeping."""

    poses: jax.Array        # (B, F, 4, 4)
    counts: jax.Array       # (B,) int32 active-frame counts
    phases: jax.Array       # (B,) int32 per-slot key-frame phases
    carries: EngineCarry    # stacked (B, ...) resume carries
    sids: Tuple[Optional[int], ...]          # slot -> session id (or None)
    enq_times: Tuple[Tuple[float, ...], ...]  # per-slot popped stamps
    slot_scene: jax.Array   # (B,) int32 index into scene_ids (idle -> 0)
    scene_ids: Tuple[Optional[int], ...]  # round's distinct scenes, local order

    @property
    def active_frames(self) -> int:
        return int(np.asarray(self.counts).sum())

    @property
    def bound_slots(self) -> int:
        return sum(s is not None for s in self.sids)


class ContinuousBatcher:
    """Scene-aware B-slot batcher over ``engine.render_streams``."""

    def __init__(self, slots: int, chunk: int, cam: Camera, *,
                 group: Optional[int] = None,
                 collect_frames: bool = False,
                 bucket: Optional[Tuple[int, int]] = None,
                 n_gaussians: Optional[int] = None,
                 tracer: Optional[Tracer] = None):
        if slots < 1 or chunk < 1:
            raise ValueError(f"need slots >= 1 and chunk >= 1, got "
                             f"{slots}, {chunk}")
        self.slots = int(slots)
        self.chunk = int(chunk)
        self.cam = cam
        # The scene bucket this batcher's slot group serves (None for
        # the single-bucket/legacy use). Purely informational — the
        # server keeps one batcher per bucket for its ragged
        # mixed-bucket rounds (DESIGN.md §11) and this tag makes traces
        # and reprs say which group is which.
        self.bucket = bucket
        # Contiguity granularity for same-scene packing; the server sets
        # this to the per-device shard size B/D. None -> one group (no
        # sharding, packing preference is moot).
        self.group = int(group) if group else self.slots
        self.collect_frames = bool(collect_frames)
        # Gaussian count of the scenes this batcher serves — required
        # when the engine config threads the contribution prior
        # (pipeline.contrib_enabled), so fresh carries match the scan
        # body's pytree structure. None = prior machinery off.
        self.n_gaussians = n_gaussians
        # Serve-loop tracer (repro/obs/trace.py): resizes are marked as
        # instant events on this batcher's bucket track, so a Perfetto
        # view shows WHEN elastic B snapped next to the round spans.
        # Defaults to the shared disabled tracer — zero overhead, no
        # None checks.
        self.tracer = NULL_TRACER if tracer is None else tracer
        self._slot_sid: List[Optional[int]] = [None] * self.slots
        # Idle slots are all identical (count 0, eye pose, zero state) —
        # one shared template instead of fresh device zeros every round.
        self._idle_carry = engine.init_carry(cam, _EYE, n_gaussians)

    @property
    def bound(self) -> int:
        return sum(s is not None for s in self._slot_sid)

    def __repr__(self) -> str:
        return (f"ContinuousBatcher(slots={self.slots}, "
                f"chunk={self.chunk}, bound={self.bound}, "
                f"bucket={self.bucket})")

    def bound_sids(self) -> List[int]:
        """Session ids currently bound to a slot, slot order."""
        return [s for s in self._slot_sid if s is not None]

    # -- elastic B ---------------------------------------------------------
    def resize(self, new_slots: int, manager: SessionManager, *,
               group: Optional[int] = None) -> List[int]:
        """Grow/shrink the slot batch between rounds (bucketed B).

        Shrinking unbinds sessions in slots >= ``new_slots``; their
        carries live on the session, so nothing is dropped — they rejoin
        ``manager.waiting()`` and resume on a later round exactly where
        they paused. Returns the unbound session ids.
        """
        if new_slots < 1:
            raise ValueError(f"need slots >= 1, got {new_slots}")
        self.tracer.instant("resize", track=f"bucket {self.bucket}",
                            args={"from": self.slots, "to": int(new_slots)})
        unbound: List[int] = []
        for i in range(new_slots, self.slots):
            sid = self._slot_sid[i]
            if sid is None:
                continue
            sess = manager.sessions.get(sid)
            if sess is not None:
                sess.slot = None
            unbound.append(sid)
        self._slot_sid = self._slot_sid[:new_slots] + \
            [None] * max(0, new_slots - self.slots)
        self.slots = int(new_slots)
        self.group = int(group) if group else self.slots
        return unbound

    # -- admission ---------------------------------------------------------
    def _slot_groups(self) -> List[range]:
        g = max(1, min(self.group, self.slots))
        return [range(s, min(s + g, self.slots))
                for s in range(0, self.slots, g)]

    def _pick_slot(self, scene_id, manager: SessionManager) -> Optional[int]:
        """Free slot preference: a group already serving ``scene_id`` >
        a fully-free group > any free slot (lowest index per tier)."""
        same = empty = anywhere = None
        for grp in self._slot_groups():
            free = [i for i in grp if self._slot_sid[i] is None]
            if not free:
                continue
            occupied = [self._slot_sid[i] for i in grp
                        if self._slot_sid[i] is not None]
            scenes_in = {manager.sessions[s].scene_id for s in occupied
                         if s in manager.sessions}
            if scene_id in scenes_in and same is None:
                same = free[0]
            if not occupied and empty is None:
                empty = free[0]
            if anywhere is None:
                anywhere = free[0]
        if same is not None:
            return same
        return empty if empty is not None else anywhere

    def admit(self, manager: SessionManager,
              allowed: Optional[Set] = None) -> int:
        """Bind waiting sessions (oldest first) to free slots, packing
        same-scene streams into contiguous groups. ``allowed`` (optional)
        restricts admission to sessions of those scene_ids — the
        server's one-scene-bucket-per-round rule."""
        admitted = 0
        for sess in manager.waiting():
            if allowed is not None and sess.scene_id not in allowed:
                continue
            i = self._pick_slot(sess.scene_id, manager)
            if i is None:
                break
            sess.slot = i
            self._slot_sid[i] = sess.sid
            admitted += 1
        return admitted

    # -- batch assembly ----------------------------------------------------
    def empty_batch(self, slots: Optional[int] = None) -> SlotBatch:
        """An all-idle (count-0) batch that touches no session state —
        shape-identical to a real round, so it drives executable warmup
        without popping poses from bound sessions. ``slots`` overrides
        the batch size (warmup across B buckets)."""
        b, f = self.slots if slots is None else int(slots), self.chunk
        carries = [self._idle_carry] * b
        stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *carries)
        return SlotBatch(poses=jnp.asarray(np.tile(_EYE, (b, f, 1, 1))),
                         counts=jnp.zeros((b,), jnp.int32),
                         phases=jnp.zeros((b,), jnp.int32), carries=stacked,
                         sids=(None,) * b, enq_times=((),) * b,
                         slot_scene=jnp.zeros((b,), jnp.int32),
                         scene_ids=())

    def build(self, manager: SessionManager) -> SlotBatch:
        """Pop up to ``chunk`` poses per bound session into a dense batch."""
        b, f = self.slots, self.chunk
        poses = np.tile(_EYE, (b, f, 1, 1))
        counts = np.zeros((b,), np.int32)
        phases = np.zeros((b,), np.int32)
        slot_scene = np.zeros((b,), np.int32)
        scene_ids: List[Optional[int]] = []
        scene_local: dict = {}
        carries: List[EngineCarry] = []
        sids: List[Optional[int]] = []
        stamps: List[Tuple[float, ...]] = []
        for i, sid in enumerate(self._slot_sid):
            sess = manager.sessions.get(sid) if sid is not None else None
            if sid is not None and sess is None:
                # Detached externally since the last round: free the slot
                # now (commit only handles cancellation mid-flight).
                self._slot_sid[i] = sid = None
            slot_stamps: List[float] = []
            if sess is not None:
                phases[i] = sess.phase
                if sess.scene_id not in scene_local:
                    scene_local[sess.scene_id] = len(scene_ids)
                    scene_ids.append(sess.scene_id)
                slot_scene[i] = scene_local[sess.scene_id]
                k = 0
                while sess.pending and k < f:
                    pose, t_enq = sess.pending.popleft()
                    poses[i, k] = pose
                    slot_stamps.append(t_enq)
                    k += 1
                counts[i] = k
                if k:
                    # Pad the tail with the last real pose: masked frames
                    # still trace the render, so keep their inputs tame.
                    poses[i, k:] = poses[i, k - 1]
                if sess.carry is None:
                    sess.carry = engine.init_carry(self.cam, poses[i, 0],
                                                   self.n_gaussians)
                carries.append(sess.carry)
                sids.append(sid)
            else:
                carries.append(self._idle_carry)
                sids.append(None)
            stamps.append(tuple(slot_stamps))
        stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *carries)
        return SlotBatch(poses=jnp.asarray(poses),
                         counts=jnp.asarray(counts),
                         phases=jnp.asarray(phases), carries=stacked,
                         sids=tuple(sids), enq_times=tuple(stamps),
                         slot_scene=jnp.asarray(slot_scene),
                         scene_ids=tuple(scene_ids))

    def commit(self, batch: SlotBatch, result: StreamsResult,
               manager: SessionManager, now: float) -> List["StreamSession"]:
        """Write back carries/latencies; detach drained sessions.

        Returns the sessions detached this round (their slots free up for
        the next ``admit``; the server keeps them for final stats).
        """
        detached: List = []
        for i, sid in enumerate(batch.sids):
            if sid is None:
                continue
            if sid not in manager.sessions:
                # Cancelled externally (manager.detach) mid-flight: the
                # rendered chunk has no consumer, but the slot must not
                # leak.
                if self._slot_sid[i] == sid:
                    self._slot_sid[i] = None
                continue
            sess = manager.sessions[sid]
            sess.carry = jax.tree_util.tree_map(lambda a, i=i: a[i],
                                                result.carries)
            n = int(np.asarray(batch.counts)[i])
            sess.frames_rendered += n
            if self.collect_frames and n:
                sess.frames.append(np.asarray(result.frames[i][:n]))
            sess.latencies.extend(now - t for t in batch.enq_times[i][:n])
            if sess.done:
                manager.detach(sid)
                sess.slot = None
                self._slot_sid[i] = None
                detached.append(sess)
        return detached
