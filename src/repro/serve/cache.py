"""Bucketed-executable cache + the 2-axis ``(B, R)`` bucket policy.

Every distinct ``(scene_bucket, B, chunk, R, window, impl)`` tuple is a
distinct XLA executable — ``impl`` (the raster kernel path, DESIGN.md
§9) changes the lowering just as surely as a shape does — so letting
the runtime-adapted shapes float with the measured workload would
compile an unbounded family. Bucketing bounds it, one axis at a time:

- **R** (``r_buckets``): ``snap_capacity`` rounds a demand estimate UP
  to the smallest bucket that covers it (the largest bucket caps
  runaway demand — overflow tiles then degrade to interpolation, which
  ``FrameRecord`` counts). ``suggest_capacity`` picks the bucket from
  *recorded* workload — the ``quantile`` of per-sparse-frame re-render
  demand (``plan.rerender_demand``: active tiles + overflow_tiles, i.e.
  what an uncapped plan would have used) — so the choice tracks the
  scenes and trajectories actually being served rather than a static
  config (ROADMAP "workload-predictive R").
- **B** (``b_buckets``): the slot-batch size snaps the same way, but is
  driven by *queue depth* — how many streams currently want service —
  instead of recorded demand (queue depth is known before the round
  renders; demand only after). Small queues ride a small batch (less
  masked-slot waste, lower per-round latency); load spikes snap the
  batch up (ROADMAP "autoscaling slot counts").
- **scene N** is bucketed at registration time by ``serve/scenes.py``
  (padded Gaussian counts), not here — the policy's job is the two
  axes that adapt *while serving*.

``BucketPolicy`` packages both serving axes; ``suggest_buckets`` is
``suggest_capacity`` grown to 2-D. The distinct-executable bound for a
server's lifetime is ``policy.max_keys`` per scene bucket in use.

``ExecutableCache`` is the bookkeeping layer: one entry per bucket key,
built lazily, with hit/miss counters the serve benchmark asserts on
(misses == distinct compilations). The entry callables own their jit
wrappers, so a cache entry IS a compiled executable after first use.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import (Callable, Deque, Dict, Hashable, Optional, Sequence,
                    Tuple)

import numpy as np

from repro.core.plan import rerender_demand
from repro.obs.trace import NULL_TRACER, Tracer

DEFAULT_R_BUCKETS = (8, 16, 32)
DEFAULT_B_BUCKETS = (2, 4, 8)


def validate_buckets(buckets: Sequence[int],
                     name: str = "r_buckets") -> None:
    """Bucket lists must be ascending and unique (snap_capacity scans in
    order, so a shuffled list would snap to the wrong executable).
    ``name`` is the argument being validated — the error must blame the
    actual offender (b_buckets/scene_buckets validate here too)."""
    if not len(buckets) or list(buckets) != \
            sorted(set(int(r) for r in buckets)):
        raise ValueError(
            f"{name} must be ascending and unique, got {buckets}")


def snap_capacity(demand: float, buckets: Sequence[int]) -> int:
    """Smallest bucket covering ``demand``; the largest bucket if none do."""
    for r in buckets:
        if demand <= r:
            return int(r)
    return int(buckets[-1])


def pick_capacity(sparse_demands, quantile: float,
                  buckets: Sequence[int]) -> int:
    """The bucket covering the ``quantile`` of per-sparse-frame demands
    (smallest bucket when nothing has been observed yet)."""
    demands = np.asarray(sparse_demands).reshape(-1)
    if demands.size == 0:
        return int(buckets[0])
    return snap_capacity(float(np.quantile(demands, quantile)), buckets)


def suggest_capacity(records, quantile: float = 0.9,
                     buckets: Sequence[int] = DEFAULT_R_BUCKETS,
                     frame_mask=None) -> int:
    """Pick ``rerender_capacity`` from recorded overflow stats.

    ``records`` is anything exposing stacked ``FrameRecord`` fields
    (``StackedRecords``, ``(F, ...)`` or ``(B, F, ...)``). Demand is
    measured on sparse frames only (full frames always re-render every
    tile); ``frame_mask`` (e.g. ``StreamsResult.frame_active``) further
    restricts to real — non-padding — frames. With no sparse frames
    observed yet, returns the smallest bucket.
    """
    active = np.asarray(records.active)
    overflow = np.asarray(records.overflow_tiles)
    is_full = np.asarray(records.is_full)
    demand = np.asarray(rerender_demand(active, overflow)).reshape(-1)
    sparse = ~is_full.reshape(-1)
    if frame_mask is not None:
        sparse &= np.asarray(frame_mask).reshape(-1)
    return pick_capacity(demand[sparse], quantile, buckets)


@dataclasses.dataclass(frozen=True)
class BucketPolicy:
    """The 2-axis serving shape policy: pick ``(B, R)`` from buckets.

    Frozen and validated at construction so a server can hold one policy
    for its lifetime; ``max_keys`` is the hard bound on distinct
    executables the policy can ever request (per scene bucket).
    """

    b_buckets: Tuple[int, ...] = DEFAULT_B_BUCKETS
    r_buckets: Tuple[int, ...] = DEFAULT_R_BUCKETS
    quantile: float = 0.9

    def __post_init__(self):
        validate_buckets(self.b_buckets, "b_buckets")
        validate_buckets(self.r_buckets, "r_buckets")
        if not 0.0 <= self.quantile <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got "
                             f"{self.quantile}")

    @property
    def max_keys(self) -> int:
        return len(self.b_buckets) * len(self.r_buckets)

    def pick_slots(self, queue_depth: int) -> int:
        """B bucket covering the streams that currently want service
        (the largest bucket caps a flood — excess streams wait)."""
        return snap_capacity(max(int(queue_depth), 1), self.b_buckets)

    def pick_capacity(self, sparse_demands) -> int:
        """R bucket covering the demand quantile (see pick_capacity)."""
        return pick_capacity(sparse_demands, self.quantile, self.r_buckets)

    def pick(self, queue_depth: int, sparse_demands) -> Tuple[int, int]:
        return self.pick_slots(queue_depth), self.pick_capacity(
            sparse_demands)


def suggest_buckets(records, queue_depth: int,
                    policy: BucketPolicy = BucketPolicy(),
                    frame_mask=None) -> Tuple[int, int]:
    """``suggest_capacity`` grown to 2 axes: ``(B, R)`` from the current
    queue depth plus recorded per-sparse-frame re-render demand."""
    r = suggest_capacity(records, policy.quantile, policy.r_buckets,
                         frame_mask)
    return policy.pick_slots(queue_depth), r


@dataclasses.dataclass
class CacheEntry:
    fn: Callable                  # instrumented dispatch wrapper
    hits: int = 0
    # Compile-vs-dispatch split (DESIGN.md §13). jit compiles lazily, so
    # the *first call* through the entry is where trace+compile cost
    # lands — its wall time is recorded here, separately from the
    # steady-state dispatch (enqueue) accumulators that every later call
    # feeds. All host-timed: a jitted call returns after compile (first
    # call) / enqueue (steady state), before device execution finishes.
    compile_seconds: Optional[float] = None
    dispatch_calls: int = 0
    dispatch_seconds: float = 0.0


class ExecutableCache:
    """Lazily-built callables keyed by bucket tuple, with hit/miss stats.

    ``log`` keeps the most recent lookups only (the counters are exact
    for the whole lifetime) so a long-running server's memory stays flat.

    Every entry's callable is wrapped to split **first-call compile**
    time from **steady-state dispatch** time per key (``stats()``
    surfaces both as ``per_key_timing``); with a ``tracer``, the first
    call additionally emits a ``compile`` span carrying the key, so the
    trace shows exactly which round paid which compile.
    """

    LOG_KEEP = 1024

    def __init__(self, tracer: Optional[Tracer] = None):
        self._entries: Dict[Hashable, CacheEntry] = {}
        self._tracer = NULL_TRACER if tracer is None else tracer
        self.misses = 0
        self.hits = 0
        self.evicted_keys = 0
        self.log: Deque[Tuple[str, Hashable]] = deque(maxlen=self.LOG_KEEP)

    def _instrument(self, key: Hashable, fn: Callable,
                    entry: CacheEntry) -> Callable:
        def dispatch(*args, **kwargs):
            if entry.compile_seconds is None:
                # First call: jit traces + compiles synchronously before
                # returning, so this wall time IS the compile bill.
                with self._tracer.span("compile", track="cache",
                                       args={"key": str(key)}):
                    t0 = time.perf_counter()
                    out = fn(*args, **kwargs)
                    entry.compile_seconds = time.perf_counter() - t0
                return out
            t0 = time.perf_counter()
            out = fn(*args, **kwargs)
            entry.dispatch_seconds += time.perf_counter() - t0
            entry.dispatch_calls += 1
            return out
        return dispatch

    def get(self, key: Hashable,
            builder: Optional[Callable[[], Callable]] = None) -> Callable:
        entry = self._entries.get(key)
        if entry is None:
            if builder is None:
                raise KeyError(key)
            self.misses += 1
            self.log.append(("miss", key))
            entry = CacheEntry(fn=None)
            entry.fn = self._instrument(key, builder(), entry)
            self._entries[key] = entry
        else:
            self.hits += 1
            entry.hits += 1
            self.log.append(("hit", key))
        return entry.fn

    def evict_keys(self, match: Callable[[Hashable], bool]) -> int:
        """Drop every entry whose key matches — the server calls this
        when a scene bucket leaves ``registry.buckets_in_use()``, so a
        scene-churning server's executable (and device-constant) memory
        stays bounded by the buckets actually in use. Returns the count
        dropped (also accumulated in ``evicted_keys``/``stats()``)."""
        doomed = [k for k in self._entries if match(k)]
        for k in doomed:
            del self._entries[k]
            self.log.append(("evict", k))
        self.evicted_keys += len(doomed)
        return len(doomed)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    @staticmethod
    def _key_str(k: Hashable):
        return list(map(str, k)) if isinstance(k, tuple) else str(k)

    def stats(self) -> dict:
        return {
            "distinct_executables": len(self._entries),
            "hits": self.hits,
            "misses": self.misses,
            "evicted_keys": self.evicted_keys,
            "keys": [self._key_str(k) for k in self._entries],
            # Per-key hit counts: which (bucket, B, R) groups actually
            # carry the traffic (the mixed-round fairness work reads
            # this next to the per-bucket latency split).
            "per_key_hits": {str(k): e.hits
                             for k, e in self._entries.items()},
            # The compile-vs-dispatch split (DESIGN.md §13): first-call
            # wall time (trace + XLA compile) next to the steady-state
            # dispatch-enqueue accumulators, per key. compile_ms is None
            # until the entry's first call (built but never invoked).
            "per_key_timing": {str(k): {
                "compile_ms": None if e.compile_seconds is None
                else round(1e3 * e.compile_seconds, 3),
                "dispatch_calls": e.dispatch_calls,
                "dispatch_ms_total": round(1e3 * e.dispatch_seconds, 3),
                "dispatch_ms_mean": round(
                    1e3 * e.dispatch_seconds / e.dispatch_calls, 3)
                if e.dispatch_calls else None,
            } for k, e in self._entries.items()},
        }
