"""Bucketed-executable cache + workload-predictive ``rerender_capacity``.

Every distinct ``(B, chunk, R, window, impl)`` tuple is a distinct XLA
executable — ``impl`` (the raster kernel path, DESIGN.md §9) changes the
lowering just as surely as a shape does — so letting R float with the
measured workload would compile an unbounded family. Two pieces bound it
(ROADMAP "workload-predictive R"):

- bucketing: R is only ever one of 2-3 fixed values
  (``ServeConfig.r_buckets``, validated ascending/unique there);
  ``snap_capacity`` rounds a demand estimate UP to the smallest bucket
  that covers it (the largest bucket caps runaway demand — overflow
  tiles then degrade to interpolation, which ``FrameRecord`` counts).
- ``suggest_capacity``: picks the bucket from *recorded* workload — the
  ``quantile`` of per-sparse-frame re-render demand
  (``plan.rerender_demand``: active tiles + overflow_tiles, i.e. what an
  uncapped plan would have used), so the choice tracks the scene and
  trajectory actually being served rather than a static config.

``ExecutableCache`` is the bookkeeping layer: one entry per bucket key,
built lazily, with hit/miss counters the serve benchmark asserts on
(misses == distinct compilations). The entry callables own their jit
wrappers, so a cache entry IS a compiled executable after first use.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import (Callable, Deque, Dict, Hashable, Optional, Sequence,
                    Tuple)

import numpy as np

from repro.core.plan import rerender_demand

DEFAULT_R_BUCKETS = (8, 16, 32)


def validate_buckets(buckets: Sequence[int]) -> None:
    """Bucket lists must be ascending and unique (snap_capacity scans in
    order, so a shuffled list would snap to the wrong executable)."""
    if not len(buckets) or list(buckets) != \
            sorted(set(int(r) for r in buckets)):
        raise ValueError(
            f"r_buckets must be ascending and unique, got {buckets}")


def snap_capacity(demand: float, buckets: Sequence[int]) -> int:
    """Smallest bucket covering ``demand``; the largest bucket if none do."""
    for r in buckets:
        if demand <= r:
            return int(r)
    return int(buckets[-1])


def pick_capacity(sparse_demands, quantile: float,
                  buckets: Sequence[int]) -> int:
    """The bucket covering the ``quantile`` of per-sparse-frame demands
    (smallest bucket when nothing has been observed yet)."""
    demands = np.asarray(sparse_demands).reshape(-1)
    if demands.size == 0:
        return int(buckets[0])
    return snap_capacity(float(np.quantile(demands, quantile)), buckets)


def suggest_capacity(records, quantile: float = 0.9,
                     buckets: Sequence[int] = DEFAULT_R_BUCKETS,
                     frame_mask=None) -> int:
    """Pick ``rerender_capacity`` from recorded overflow stats.

    ``records`` is anything exposing stacked ``FrameRecord`` fields
    (``StackedRecords``, ``(F, ...)`` or ``(B, F, ...)``). Demand is
    measured on sparse frames only (full frames always re-render every
    tile); ``frame_mask`` (e.g. ``StreamsResult.frame_active``) further
    restricts to real — non-padding — frames. With no sparse frames
    observed yet, returns the smallest bucket.
    """
    active = np.asarray(records.active)
    overflow = np.asarray(records.overflow_tiles)
    is_full = np.asarray(records.is_full)
    demand = np.asarray(rerender_demand(active, overflow)).reshape(-1)
    sparse = ~is_full.reshape(-1)
    if frame_mask is not None:
        sparse &= np.asarray(frame_mask).reshape(-1)
    return pick_capacity(demand[sparse], quantile, buckets)


@dataclasses.dataclass
class CacheEntry:
    fn: Callable
    hits: int = 0


class ExecutableCache:
    """Lazily-built callables keyed by bucket tuple, with hit/miss stats.

    ``log`` keeps the most recent lookups only (the counters are exact
    for the whole lifetime) so a long-running server's memory stays flat.
    """

    LOG_KEEP = 1024

    def __init__(self):
        self._entries: Dict[Hashable, CacheEntry] = {}
        self.misses = 0
        self.hits = 0
        self.log: Deque[Tuple[str, Hashable]] = deque(maxlen=self.LOG_KEEP)

    def get(self, key: Hashable,
            builder: Optional[Callable[[], Callable]] = None) -> Callable:
        entry = self._entries.get(key)
        if entry is None:
            if builder is None:
                raise KeyError(key)
            self.misses += 1
            self.log.append(("miss", key))
            entry = self._entries[key] = CacheEntry(fn=builder())
        else:
            self.hits += 1
            entry.hits += 1
            self.log.append(("hit", key))
        return entry.fn

    def __contains__(self, key: Hashable) -> bool:
        return key in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    def stats(self) -> dict:
        return {
            "distinct_executables": len(self._entries),
            "hits": self.hits,
            "misses": self.misses,
            "keys": [list(map(str, k)) if isinstance(k, tuple) else str(k)
                     for k in self._entries],
        }
