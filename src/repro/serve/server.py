"""The serve loop: scenes + sessions -> admission -> per-bucket batchers.

One ``StreamServer.step()`` is a *ragged mixed-bucket round* (DESIGN.md
§11): the admission controller (serve/admission.py) plans which scene
buckets render this round from per-bucket demand (aging guarantees no
bucket waits more than ``max_wait_rounds``; SLO classes bias ordering
and the elastic-B resize), then every planned bucket GROUP — one
``ContinuousBatcher`` per scene bucket, since a batch can only stack
same-bucket scenes — resizes, admits its waiting streams, builds its
(B, chunk) batch, and dispatches through its own cached executable.
Dispatch is asynchronous: all groups are launched back to back and ONE
``block_until_ready`` barrier closes the round, so a small bucket's
kernel overlaps a big bucket's instead of waiting whole rounds behind
it — the paper's no-stall thesis applied at fleet scale (the same
pytrees-of-same-shape-leaf-groups idiom jax.experimental.treevec uses:
group leaves by shape signature, vectorize per group, recombine). All
groups' carries commit together after the barrier.

Scenes come from a ``SceneRegistry`` (serve/scenes.py): pass one with
scenes pre-registered, or pass a bare ``GaussianScene`` and the server
registers it as the single default scene (the PR-3 single-scene server
is exactly this degenerate case). Sessions are keyed by ``scene_id``;
each group's distinct scenes are stacked ``(B, N_bucket, ...)`` and the
engine gathers per slot (``slot_scene``), so any mix of same-bucket
scenes rides ONE executable — the cache key is
``(scene_bucket, B, chunk, R, window, impl)`` and never names a scene.

Serving shapes stay workload-adaptive through ``cache.BucketPolicy``:
R re-picks every ``adapt_every`` busy rounds from a rolling history of
recorded re-render demand; each bucket's B re-snaps every round from
that bucket's (SLO-weighted) queue depth. With 2-3 buckets per axis the
distinct compilations stay bounded by ``policy.max_keys`` per scene
bucket in use no matter how long the server runs (asserted in
benchmarks/serve_bench.py), and ``evict_scene`` drops executables whose
scene bucket left use, so a scene-churning server's device memory stays
bounded too.

Backpressure: with ``AdmissionConfig.max_waiting`` set, ``attach``
raises ``AdmissionRejected`` once the waiting set is full (``try_attach``
returns None instead; ``run`` defers the arrival and retries next
round). ``report()`` publishes per-bucket p50/p99 latency, per-bucket
max wait, and a Jain fairness index over service shares next to the
global metrics.

``sim_latency=True`` closes the loop with the paper's accelerator model:
every rendered frame's ``FrameRecord`` (with its recorded device-LDU
schedule) is folded into a bounded trace and ``report()`` replays it
through ``core/streaming.simulate_sequence(policy="recorded")`` — so
serve_bench.json shows the simulated ASIC cycles next to the wall-clock
latencies for the very frames this process served.

Traffic: ``PoissonTraffic`` drives the steady-state benchmarks (Poisson
arrivals of heterogeneous dolly/orbit trajectories round-robined over
scenes); ``ReplayTraffic`` replays a deterministic arrival trace —
``skewed_trace`` (10:1 bucket skew, the starvation reproducer) and
``burst_trace`` (quiet rounds punctuated by arrival bursts) build the
traces benchmarks/serve_bench.py uses for its before/after fairness
comparison.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import deque
from typing import (Deque, Dict, List, Optional, Sequence, Tuple, Union)

import jax
import numpy as np

from repro.core.camera import Camera
from repro.core.gaussians import GaussianScene
from repro.core.pipeline import (RenderConfig, StackedRecords,
                                 contrib_enabled)
from repro.core.plan import rerender_demand
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer
from repro.core.streaming import (AcceleratorConfig, FrameWork,
                                  frameworks_from_stacked,
                                  simulate_sequence, throughput)
from repro.scenes.trajectory import dolly_trajectory, orbit_trajectory
from repro.serve.admission import (AdmissionConfig, AdmissionController,
                                   AdmissionRejected, BucketDemand)
from repro.serve.batcher import ContinuousBatcher
from repro.serve.cache import (BucketPolicy, ExecutableCache,
                               validate_buckets)
from repro.serve.placement import build_render_fn, stream_mesh
from repro.serve.scenes import DEFAULT_SCENE_BUCKETS, SceneRegistry
from repro.serve.session import SessionManager, StreamSession


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    slots: int = 8              # B: stream slots (static, if b_buckets unset)
    chunk: int = 4              # F: frames per stream per round
    r_buckets: Tuple[int, ...] = (8, 16, 32)
    # B buckets for the elastic slot batch; None = static B (`slots`).
    b_buckets: Optional[Tuple[int, ...]] = None
    quantile: float = 0.9       # demand quantile for capacity selection
    adapt_every: int = 4        # rounds between R re-evaluation
    history: int = 4096         # demand samples kept for the quantile
    use_sharding: bool = True   # shard slots over devices when possible
    scene_buckets: Tuple[int, ...] = DEFAULT_SCENE_BUCKETS
    collect_frames: bool = False  # retain rendered frames on sessions
    sim_latency: bool = False   # accelerator-in-the-loop metrics
    sim_keep: int = 4096        # most recent frames kept for the sim
    # Observability (repro/obs, DESIGN.md §13): ``trace=True`` records
    # round/plan/resize/admit/build/dispatch/barrier/commit spans (one
    # track per scene-bucket group) plus per-key compile spans, exported
    # as Chrome-trace JSON via ``StreamServer.tracer``. Off by default —
    # a disabled tracer's span() is a shared no-op. The metrics registry
    # is always on (host counters; report() composes its snapshot).
    trace: bool = False
    trace_keep: int = Tracer.KEEP  # tracer event-buffer bound
    # Round planning + backpressure + SLO classes (serve/admission.py).
    admission: AdmissionConfig = AdmissionConfig()

    def __post_init__(self):
        validate_buckets(self.r_buckets, "r_buckets")
        if self.b_buckets is not None:
            validate_buckets(self.b_buckets, "b_buckets")
        validate_buckets(self.scene_buckets, "scene_buckets")
        if self.trace_keep < 1:
            raise ValueError(f"trace_keep must be >= 1, got "
                             f"{self.trace_keep}")

    @property
    def slot_buckets(self) -> Tuple[int, ...]:
        """The B values this server may run (static B = one bucket)."""
        return self.b_buckets if self.b_buckets is not None \
            else (self.slots,)


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    n_streams: int = 12         # total arrivals over the run
    rate: float = 2.0           # mean arrivals per round (Poisson)
    min_frames: int = 6
    max_frames: int = 16
    seed: int = 0
    scenes: int = 1             # round-robin arrivals over this many scenes


def sample_trajectory(rng: np.random.Generator,
                      cfg: TrafficConfig) -> np.ndarray:
    """One heterogeneous dolly/orbit trajectory (shared by both traffic
    generators so a replay trace and a Poisson run draw from the same
    pose distribution)."""
    n = int(rng.integers(cfg.min_frames, cfg.max_frames + 1))
    if rng.random() < 0.5:
        dx, dy = rng.uniform(-0.4, 0.4), rng.uniform(-0.4, 0.1)
        return np.asarray(dolly_trajectory(
            n, start=(dx, dy, rng.uniform(-3.0, -1.5)),
            target=(0.0, 0.0, 6.0)))
    return np.asarray(orbit_trajectory(
        n, radius=rng.uniform(5.0, 8.0), target=(0.0, 0.0, 6.0),
        height=rng.uniform(-1.0, 0.0)))


class PoissonTraffic:
    """Poisson arrivals of heterogeneous trajectories over K scenes."""

    def __init__(self, cfg: TrafficConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.remaining = int(cfg.n_streams)
        self.arrived = 0

    @property
    def done(self) -> bool:
        return self.remaining <= 0

    def arrivals(self) -> List[Tuple[np.ndarray, int]]:
        """This round's ``(poses, scene_index)`` arrivals; scene_index
        round-robins over ``cfg.scenes`` (the server maps it onto its
        registered scene ids)."""
        if self.done:
            return []
        k = int(min(self.rng.poisson(self.cfg.rate), self.remaining))
        self.remaining -= k
        out = []
        for _ in range(k):
            out.append((sample_trajectory(self.rng, self.cfg),
                        self.arrived % max(self.cfg.scenes, 1)))
            self.arrived += 1
        return out


def skewed_trace(n_streams: int, skew: int = 10,
                 majority_scene: int = 0,
                 minority_scene: int = 1) -> List[List[int]]:
    """Arrival trace with ``skew``:1 per-round bucket skew — each round
    brings ``skew`` majority-scene streams then ONE minority-scene
    stream (the minority arrives last so drain-mode scheduling shows
    its worst case) until ``n_streams`` have arrived. The starvation
    reproducer: under drain-before-switch the minority waits for the
    whole majority backlog; under mixed rounds + aging its max wait is
    bounded by ``max_wait_rounds``."""
    if skew < 1:
        raise ValueError(f"skew must be >= 1, got {skew}")
    trace: List[List[int]] = []
    n = 0
    while n < n_streams:
        rnd = [majority_scene] * min(skew, n_streams - n)
        n += len(rnd)
        if n < n_streams:
            rnd.append(minority_scene)
            n += 1
        trace.append(rnd)
    return trace


def burst_trace(n_streams: int, burst_every: int = 4,
                burst_size: int = 6, scenes: int = 2) -> List[List[int]]:
    """Quiet rounds punctuated by bursts: every ``burst_every`` rounds,
    ``burst_size`` streams arrive at once, round-robined over
    ``scenes`` scene indices — the backpressure/aging stressor (a burst
    overfills the waiting set, then the queue drains over the quiet
    rounds)."""
    if burst_every < 1 or burst_size < 1:
        raise ValueError(f"burst_every and burst_size must be >= 1, got "
                         f"{burst_every}, {burst_size}")
    trace: List[List[int]] = []
    n = 0
    while n < n_streams:
        trace.extend([[]] * (burst_every - 1))
        burst = [i % max(scenes, 1)
                 for i in range(n, min(n + burst_size, n_streams))]
        n += len(burst)
        trace.append(burst)
    return trace


class ReplayTraffic:
    """Deterministic arrival replay: ``trace`` is a list of per-round
    scene-index lists (see ``skewed_trace``/``burst_trace``); each entry
    becomes one arrival with a trajectory sampled from ``cfg``'s pose
    distribution. Same ``arrivals()``/``done`` protocol as
    ``PoissonTraffic`` — ``StreamServer.run`` takes either."""

    def __init__(self, trace: Sequence[Sequence[int]], cfg: TrafficConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self._rounds: Deque[List[int]] = deque(list(r) for r in trace)
        self.arrived = 0

    @property
    def done(self) -> bool:
        return not self._rounds

    def arrivals(self) -> List[Tuple[np.ndarray, int]]:
        if self.done:
            return []
        out = [(sample_trajectory(self.rng, self.cfg), int(idx))
               for idx in self._rounds.popleft()]
        self.arrived += len(out)
        return out


class StreamServer:
    """Multi-scene continuous-batching stream server (module docstring)."""

    TRACE_KEEP = 1024     # most recent per-round dicts kept for report()
    LATENCY_KEEP = 65536  # most recent per-frame latency samples kept
    STACK_KEEP = 8        # memoized per-round scene stacks

    def __init__(self, scene: Union[GaussianScene, SceneRegistry],
                 cam: Camera, base_cfg: RenderConfig,
                 scfg: ServeConfig = ServeConfig()):
        if isinstance(scene, SceneRegistry):
            self.registry = scene
            if not len(self.registry):
                raise ValueError("SceneRegistry has no scenes registered")
        else:
            self.registry = SceneRegistry(scfg.scene_buckets)
            self.registry.register(scene)
        self.cam = cam
        self.base_cfg = base_cfg
        self.scfg = scfg
        # Observability substrate (repro/obs, DESIGN.md §13): ONE metrics
        # registry every serve component publishes into — report()
        # composes its snapshot() instead of re-deriving ad-hoc dicts —
        # and ONE tracer whose spans the serving round opens below.
        self.metrics = MetricsRegistry()
        self.tracer = Tracer(enabled=scfg.trace, keep=scfg.trace_keep)
        m = self.metrics
        self._m_streams = m.counter("serve_streams_attached_total",
                                    "streams admitted via attach()")
        self._m_finished = m.counter("serve_streams_finished_total",
                                     "streams drained and detached")
        self._m_rounds = m.counter("serve_rounds_total",
                                   "step() invocations")
        self._m_busy = m.counter("serve_busy_rounds_total",
                                 "rounds that rendered at least one group")
        self._m_frames = m.counter("serve_frames_total",
                                   "real (non-padding) frames rendered")
        self._m_cap_frames = m.counter(
            "serve_capacity_frames_total",
            "sum of B*chunk slot-frames over rendered groups")
        self._m_render_s = m.counter("serve_render_seconds_total",
                                     "wall seconds inside serving rounds")
        self._m_warmup_s = m.counter("serve_warmup_seconds_total",
                                     "wall seconds inside warmup()")
        self._m_concurrent = m.gauge("serve_max_concurrent_streams",
                                     "peak streams bound to slots")
        self._m_trace_drop = m.counter(
            "serve_rounds_trace_dropped_total",
            "per-round trace dicts evicted from the bounded deque")
        # Bounded latency/device-work histograms: lifetime count/sum are
        # exact, percentiles are over the newest LATENCY_KEEP samples —
        # finished StreamSession objects are NOT retained (a churning
        # server would otherwise grow memory without bound). Per-bucket
        # latency histograms feed the fairness split in report().
        self._m_latency = m.histogram(
            "serve_latency_seconds", "per-frame enqueue -> render-complete",
            keep=self.LATENCY_KEEP)
        self._m_sort_pairs = m.histogram(
            "device_sort_pairs", "pairs entering the per-frame sort",
            keep=scfg.history)
        self._m_culled = m.histogram(
            "device_culled_pairs", "pairs removed by contribution culling",
            keep=scfg.history)
        self._m_demand = m.histogram(
            "device_rerender_demand",
            "re-render tiles wanted per sparse frame (pre-cap)",
            keep=scfg.history)
        self.policy = BucketPolicy(b_buckets=scfg.slot_buckets,
                                   r_buckets=scfg.r_buckets,
                                   quantile=scfg.quantile)
        self.manager = SessionManager(base_cfg.window)
        self.admission = AdmissionController(scfg.admission,
                                             metrics=self.metrics)
        self._meshes: Dict[int, object] = {}
        # One batcher per scene bucket in use (the ragged mixed-bucket
        # round's slot groups — a batch can only stack same-bucket
        # scenes, so the bucket IS the group signature). Created eagerly
        # for registered buckets, lazily for buckets registered later.
        self._batchers: Dict[Tuple[int, int], ContinuousBatcher] = {}
        for bucket in self.registry.buckets_in_use():
            self._batcher_for(bucket)
        self.cache = ExecutableCache(tracer=self.tracer)
        self.capacity = int(scfg.r_buckets[0])
        self.capacity_history: List[int] = [self.capacity]
        self.slots_history: List[int] = [scfg.slot_buckets[0]]
        # Bounded per-round trace (the `rounds_trace` report block):
        # newest TRACE_KEEP round dicts; evictions are counted and
        # published as rounds_trace_dropped so a long-lived server's
        # report says how much history the bound cost it.
        self.trace: Deque[dict] = deque(maxlen=self.TRACE_KEEP)
        # Rolling per-sparse-frame demand samples (flat ints — all the
        # capacity picker needs), newest last.
        self._demand: Deque[int] = deque(maxlen=scfg.history)
        # Accelerator-in-the-loop trace: per-group device-side records
        # in service order (host conversion is deferred to report() so
        # the serving rounds never pay record transfers), bounded like
        # the latency reservoir.
        self._sim_rounds: Deque[tuple] = deque(
            maxlen=max(1, scfg.sim_keep // max(scfg.chunk, 1)))
        self._sim_dropped = 0
        self._stacks: Dict[tuple, object] = {}

    # -- metrics-backed counters -------------------------------------------
    # The registry is the single source of truth (report() composes its
    # snapshot); these properties keep the original attribute API for
    # callers and tests.
    @property
    def streams_seen(self) -> int:
        return int(self._m_streams.value)

    @property
    def streams_finished(self) -> int:
        return int(self._m_finished.value)

    @property
    def rounds(self) -> int:
        return int(self._m_rounds.value)

    @property
    def busy_rounds(self) -> int:
        return int(self._m_busy.value)

    @property
    def active_slot_frames(self) -> int:
        return int(self._m_frames.value)

    @property
    def capacity_frames(self) -> int:
        return int(self._m_cap_frames.value)

    @property
    def render_seconds(self) -> float:
        return float(self._m_render_s.value)

    @property
    def warmup_seconds(self) -> float:
        return float(self._m_warmup_s.value)

    @property
    def max_concurrent(self) -> int:
        return int(self._m_concurrent.value)

    # -- scenes ------------------------------------------------------------
    @property
    def default_scene_id(self) -> int:
        return self.registry.ids()[0]

    def register_scene(self, scene: GaussianScene):
        """Admit a new scene mid-serving; invalidates memoized stacks."""
        entry = self.registry.register(scene, now=self.clock())
        self._stacks.clear()
        return entry

    def evict_scene(self, scene_id: int):
        """Evict a drained scene (raises while streams are attached).

        If the scene's bucket leaves ``registry.buckets_in_use()``, the
        bucket's batcher (device-resident idle carries) and every cached
        executable keyed on that bucket are dropped too — a long-running
        server that churns scenes across buckets must not grow device
        memory without bound (``cache.stats()["evicted_keys"]`` counts
        the drops)."""
        entry = self.registry.evict(scene_id)
        self._stacks.clear()
        if entry.bucket not in self.registry.buckets_in_use():
            self._batchers.pop(entry.bucket, None)
            self.cache.evict_keys(lambda k: k[0] == entry.bucket)
        return entry

    def scene_for_index(self, idx: int) -> int:
        """Traffic scene index -> registered scene id (round-robin)."""
        ids = self.registry.ids()
        return ids[idx % len(ids)]

    # -- lifecycle ---------------------------------------------------------
    def clock(self) -> float:
        return time.perf_counter()

    def attach(self, poses, now: Optional[float] = None,
               scene_id: Optional[int] = None,
               slo: Optional[str] = None) -> StreamSession:
        """Attach a stream, or raise ``AdmissionRejected`` when the
        waiting set is full (``AdmissionConfig.max_waiting`` — the
        backpressure contract; use ``try_attach`` for a non-raising
        probe). ``slo`` names a service class from
        ``AdmissionConfig.slo_classes``."""
        sid = self.default_scene_id if scene_id is None else scene_id
        self.registry.get(sid)         # raises on unknown scene
        self.scfg.admission.slo(slo)   # raises on unknown SLO class
        if not self.admission.offer(len(self.manager.waiting())):
            raise AdmissionRejected(
                f"waiting set is full "
                f"({self.scfg.admission.max_waiting}); retry later")
        sess = self.manager.attach(
            poses, now=self.clock() if now is None else now, scene_id=sid,
            slo=slo)
        self.registry.acquire(sid)     # pin only once the attach stuck
        self._m_streams.inc()
        return sess

    def try_attach(self, poses, now: Optional[float] = None,
                   scene_id: Optional[int] = None,
                   slo: Optional[str] = None) -> Optional[StreamSession]:
        """``attach`` that returns None instead of raising on
        backpressure (the defer signal for callers that retry)."""
        try:
            return self.attach(poses, now=now, scene_id=scene_id, slo=slo)
        except AdmissionRejected:
            return None

    def detach(self, sid: int) -> StreamSession:
        """Cancel a stream mid-flight: remove its session AND release its
        scene pin. Server-attached streams must be cancelled here, not
        via ``manager.detach`` directly — the manager knows nothing of
        the registry, so a direct detach would leave ``entry.refs``
        pinned forever and block ``evict_scene``. (The batcher reclaims
        the cancelled stream's slot on the next round.)"""
        sess = self.manager.detach(sid)
        self.registry.release(sess.scene_id)
        return sess

    # -- executable selection ----------------------------------------------
    def _key_for(self, bucket, b: int, r: int):
        # scene_bucket is the (padded N, sh K) shape signature; impl is
        # the raster kernel path (DESIGN.md §9) — both change the
        # lowering, and a server serving many scenes or reconfigured
        # across backends must never reuse a stale executable.
        return (bucket, int(b), self.scfg.chunk, int(r),
                self.base_cfg.window, self.base_cfg.impl)

    def _mesh_for(self, b: int):
        if not self.scfg.use_sharding:
            return None
        if b not in self._meshes:
            self._meshes[b] = stream_mesh(b)
        return self._meshes[b]

    def _group_for(self, b: int) -> int:
        mesh = self._mesh_for(b)
        return b // int(mesh.size) if mesh is not None else b

    def _build_for(self, b: int, r: int):
        cfg = dataclasses.replace(self.base_cfg, rerender_capacity=int(r))
        return build_render_fn(self.cam, cfg, self._mesh_for(b),
                               multi_scene=True)

    def _executable(self, bucket, b: int):
        r = self.capacity
        return self.cache.get(self._key_for(bucket, b, r),
                              lambda: self._build_for(b, r))

    def _batcher_for(self, bucket) -> ContinuousBatcher:
        bat = self._batchers.get(bucket)
        if bat is None:
            b0 = self.scfg.slot_buckets[0]
            # With the contribution prior threaded (contrib_enabled),
            # carries hold an (N,) leaf — N is the bucket's padded
            # Gaussian count, so every scene in the bucket shares one
            # carry structure.
            n = bucket[0] if contrib_enabled(self.base_cfg) \
                else None
            bat = ContinuousBatcher(
                b0, self.scfg.chunk, self.cam, group=self._group_for(b0),
                collect_frames=self.scfg.collect_frames, bucket=bucket,
                n_gaussians=n, tracer=self.tracer)
            self._batchers[bucket] = bat
        return bat

    @property
    def batcher(self) -> ContinuousBatcher:
        """The sole in-use batcher — single-bucket convenience (tests,
        the degenerate single-scene server). Ambiguous with multiple
        buckets in flight: use ``batcher_for`` then."""
        if len(self._batchers) == 1:
            return next(iter(self._batchers.values()))
        raise ValueError(
            f"{len(self._batchers)} bucket batchers in use "
            f"({list(self._batchers)}); use batcher_for(bucket)")

    def batcher_for(self, bucket) -> ContinuousBatcher:
        """The slot-group batcher serving ``bucket`` (created on first
        use)."""
        return self._batcher_for(bucket)

    @property
    def total_bound(self) -> int:
        """Streams bound to a slot across every bucket group."""
        return sum(bat.bound for bat in self._batchers.values())

    def _stack_for(self, scene_ids: Tuple[Optional[int], ...],
                   bucket, size: int):
        """Round's stacked (size, N_bucket, ...) scenes, memoized while
        the bound scene set is stable across rounds."""
        ids = tuple(self.default_scene_id if i is None else i
                    for i in scene_ids)
        if not ids:
            ids = (self.registry.by_bucket(bucket)[0],)
        key = (ids, int(size))
        if key not in self._stacks:
            if len(self._stacks) >= self.STACK_KEEP:
                self._stacks.pop(next(iter(self._stacks)))
            self._stacks[key] = self.registry.stack(ids, size)
        return self._stacks[key]

    def warmup(self) -> float:
        """Compile every (scene_bucket, B, R) executable before traffic.

        Runs each combination once on an all-masked (count-0) batch so
        jit compile cost lands here instead of inside the first serving
        rounds' latencies. Returns wall seconds spent THIS call;
        ``warmup_seconds`` accumulates across calls (a server warmed
        again after ``register_scene`` must not forget the first bill).
        Optional — an unwarmed server lazily compiles (at most) one
        executable per key on first use, it just bills that to the
        unlucky round. Safe mid-serving: the warmup batch is synthesized
        from scratch (``empty_batch``), never popping bound sessions'
        poses, and warmup scene stacks deliberately bypass the bounded
        ``_stacks`` memo — warming every (bucket, B) combination would
        otherwise evict the in-flight rounds' live stack keys.
        """
        t0 = self.clock()
        with self.tracer.span("warmup", track="round"):
            for bucket in self.registry.buckets_in_use():
                ids = (self.registry.by_bucket(bucket)[0],)
                bat = self._batcher_for(bucket)
                for b in self.policy.b_buckets:
                    batch = bat.empty_batch(slots=b)
                    # Transient stack: NOT memoized (see docstring).
                    scenes = self.registry.stack(ids, b)
                    for r in self.policy.r_buckets:
                        fn = self.cache.get(
                            self._key_for(bucket, b, r),
                            lambda b=b, r=r: self._build_for(b, r))
                        jax.block_until_ready(fn(
                            scenes, batch.poses, batch.counts, batch.phases,
                            batch.carries, batch.slot_scene).frames)
        spent = self.clock() - t0
        self._m_warmup_s.inc(spent)
        return spent

    # -- adaptive shapes ---------------------------------------------------
    def _bucket_of(self, sess: StreamSession) -> Tuple[int, int]:
        sid = self.default_scene_id if sess.scene_id is None \
            else sess.scene_id
        return self.registry.bucket_of(sid)

    def _bucket_demand(self) -> Dict[Tuple[int, int], BucketDemand]:
        """Per-bucket demand snapshot for the admission controller:
        streams wanting service (bound, or waiting with pending poses),
        their SLO weights, and the oldest-stream order tiebreak."""
        demand: Dict[Tuple[int, int], BucketDemand] = {}
        for s in self.manager.sessions.values():
            if s.slot is None and not s.pending:
                continue
            b = self._bucket_of(s)
            d = demand.setdefault(b, BucketDemand())
            cls = self.scfg.admission.slo(s.slo)
            d.depth += 1
            # weight >= 1 inflates effective depth (snaps B up sooner);
            # < 1 never shrinks it below the true queue.
            d.weighted_depth += max(1.0, cls.weight)
            d.weight = max(d.weight, cls.weight)
            d.order = min(d.order, s.sid)
            if s.slot is not None:
                d.bound += 1
            if s.pending:
                d.pending += 1
            if cls.max_wait_rounds is not None:
                d.wait_bound = cls.max_wait_rounds if d.wait_bound is None \
                    else min(d.wait_bound, cls.max_wait_rounds)
        return demand

    def _maybe_resize(self, bucket, d: BucketDemand) -> None:
        """Snap this bucket's B to the bucket covering its SLO-weighted
        queue depth (elastic B). The batcher resize unbinds overflow
        sessions on shrink — carries stay on the sessions, so the
        resize drops nothing."""
        if self.scfg.b_buckets is None:
            return
        bat = self._batcher_for(bucket)
        b = self.policy.pick_slots(int(math.ceil(d.weighted_depth)))
        if b != bat.slots:
            bat.resize(b, self.manager, group=self._group_for(b))
            self.slots_history.append(b)

    def _observe(self, result) -> None:
        """Fold a group's records into the demand history; re-pick R.

        Only real (non-padding) sparse frames contribute demand samples
        — ``plan.rerender_demand`` per frame, the same statistic
        ``cache.suggest_capacity`` computes from raw records. The adapt
        cadence counts BUSY rounds (this method only runs on those), so
        traffic gaps never starve adaptation.
        """
        recs = result.records
        mask = np.asarray(result.frame_active).reshape(-1)
        sparse = mask & ~np.asarray(recs.is_full).reshape(-1)
        # Device-work histograms (DESIGN.md §13): per-frame sort pairs
        # and culled pairs over real frames, re-render demand over real
        # sparse frames — derived from the SAME device records the
        # engine was already returning, so observing costs no extra
        # transfers beyond the np.asarray the demand path always paid.
        t = np.asarray(recs.sort_pairs)
        self._m_sort_pairs.observe_many(
            t.reshape(-1, t.shape[-1]).sum(axis=-1)[mask])
        self._m_culled.observe_many(
            np.asarray(recs.culled_pairs).reshape(-1)[mask])
        if sparse.any():
            demand = np.asarray(rerender_demand(
                recs.active, recs.overflow_tiles)).reshape(-1)
            self._demand.extend(demand[sparse].tolist())
            self._m_demand.observe_many(demand[sparse])
        if self._demand and self.busy_rounds % self.scfg.adapt_every == 0:
            new_cap = self.policy.pick_capacity(list(self._demand))
            if new_cap != self.capacity:
                self.capacity = new_cap
                self.capacity_history.append(new_cap)

    # -- accelerator-in-the-loop -------------------------------------------
    def _record_sim(self, batch, result) -> None:
        """Stash a group's stacked records (device references — ONE
        deque append, no host transfer on the serving path; the
        FrameWork conversion is deferred to ``_sim_report`` so recording
        never inflates the wall-clock latencies being measured)."""
        counts = np.asarray(batch.counts)
        active = tuple(s is not None and counts[i] > 0
                       for i, s in enumerate(batch.sids))
        if self._sim_rounds.maxlen and \
                len(self._sim_rounds) == self._sim_rounds.maxlen:
            _, old_counts, old_active = self._sim_rounds[0]
            self._sim_dropped += int(sum(
                c for c, a in zip(old_counts, old_active) if a))
        self._sim_rounds.append((result.records.stacked, counts, active))

    def _sim_frameworks(self) -> Tuple[List[FrameWork], int]:
        """Host-convert the stashed groups into per-frame FrameWorks,
        service order (round-major, slot order within a group). Returns
        ``(frames, tail_trimmed)`` — the deque bounds round memory, the
        ``sim_keep`` trim bounds the sim itself, and the trim count
        must reach the drop accounting (report-time, no mutation: the
        deque-evicted drops live in ``_sim_dropped``; summing both at
        report keeps ``report()`` idempotent)."""
        frames: List[FrameWork] = []
        n_px = self.cam.height * self.cam.width
        for stacked, counts, active in self._sim_rounds:
            for i, on in enumerate(active):
                if not on:
                    continue
                recs = jax.tree_util.tree_map(lambda a, i=i: a[i], stacked)
                frames.extend(frameworks_from_stacked(
                    StackedRecords(recs), self.cam.tiles_x,
                    self.cam.tiles_y, n_px)[:counts[i]])
        trimmed = max(0, len(frames) - self.scfg.sim_keep)
        return frames[-self.scfg.sim_keep:], trimmed

    def _sim_report(self) -> Optional[dict]:
        """Replay the served frames through the accelerator model —
        simulated ASIC cycles for the exact schedules the jitted engine
        recorded (policy="recorded", streaming pipeline on)."""
        frames, trimmed = self._sim_frameworks()
        if not frames:
            return None
        acfg = AcceleratorConfig(num_blocks=self.base_cfg.ldu_blocks)
        timings = simulate_sequence(frames, acfg, policy="recorded",
                                    streaming=True)
        agg = throughput(timings, acfg.num_blocks)
        # Per-frame service latency in the streaming pipeline: the gap
        # this frame adds to the completion front (frame_end is
        # monotone; overlapped frames add less than their span).
        ends = np.asarray([t.frame_end for t in timings])
        service = np.diff(ends, prepend=0.0)
        return {
            "frames": len(frames),
            # BOTH drop paths: rounds evicted from the bounded deque
            # (_sim_dropped) AND the report-time tail trim to sim_keep.
            "frames_dropped": self._sim_dropped + trimmed,
            "cycles_per_frame": round(float(agg["cycles_per_frame"]), 1),
            "utilization": round(float(agg["utilization"]), 4),
            "sort_stall_cycles": round(float(agg["sort_stall"]), 1),
            "latency_p50_cycles": round(float(np.percentile(service, 50)),
                                        1),
            "latency_p99_cycles": round(float(np.percentile(service, 99)),
                                        1),
        }

    # -- the serving round -------------------------------------------------
    def _bucket_latency(self, bucket) -> "object":
        """The per-scene-bucket latency histogram (labeled family of
        ``serve_latency_seconds``) — get-or-create, so report() can read
        a bucket that never rendered and see None percentiles."""
        return self.metrics.histogram(
            "serve_latency_seconds",
            "per-frame enqueue -> render-complete",
            keep=self.LATENCY_KEEP, bucket=str(bucket))

    def _push_round(self, info: dict) -> None:
        """Append to the bounded rounds_trace, counting the eviction the
        bound forces (report() publishes rounds_trace_dropped)."""
        if len(self.trace) == self.trace.maxlen:
            self._m_trace_drop.inc()
        self.trace.append(info)

    def step(self) -> dict:
        self._m_rounds.inc()
        rnd = self.rounds
        tr = self.tracer
        with tr.span("round", track="round", args={"round": rnd}):
            with tr.span("plan", track="round"):
                demand = self._bucket_demand()
                plan = self.admission.plan_round(demand)
            t0 = self.clock()
            # Launch every planned bucket group back to back (async
            # dispatch): group k+1's host-side batch build overlaps
            # group k's device execution, and the single barrier below
            # closes the whole ragged round. Each group's host phases
            # get spans on the group's own track ("bucket <sig>") so the
            # trace shows the per-bucket pipelining the round relies on.
            groups = []
            for bucket in plan:
                tk = f"bucket {bucket}"
                bat = self._batcher_for(bucket)
                with tr.span("resize", track=tk):
                    self._maybe_resize(bucket, demand[bucket])
                with tr.span("admit", track=tk):
                    bat.admit(self.manager,
                              allowed=set(self.registry.by_bucket(bucket)))
                with tr.span("build", track=tk):
                    batch = bat.build(self.manager)
                if batch.active_frames == 0:
                    continue
                key = self._key_for(bucket, bat.slots, self.capacity)
                with tr.span("dispatch", track=tk,
                             args={"key": str(key),
                                   "frames": batch.active_frames}):
                    scenes = self._stack_for(batch.scene_ids, bucket,
                                             bat.slots)
                    fn = self._executable(bucket, bat.slots)
                    result = fn(scenes, batch.poses, batch.counts,
                                batch.phases, batch.carries,
                                batch.slot_scene)
                groups.append((bucket, bat, batch, result))
            self._m_concurrent.set_max(self.total_bound)
            served = [bucket for bucket, *_ in groups]
            self.admission.note_round(demand, served)
            if not groups:
                info = {"round": rnd, "frames": 0, "bound_slots": 0,
                        "groups": [], "capacity": self.capacity}
                self._push_round(info)
                return info
            with tr.span("barrier", track="round",
                         args={"groups": len(groups)}):
                jax.block_until_ready([(res.frames, res.carries)
                                       for *_, res in groups])
            t1 = self.clock()
            self._m_busy.inc()         # before _observe: its adapt cadence
            total_frames = 0
            group_infos = []
            scene_ids_served: List[int] = []
            for bucket, bat, batch, result in groups:
                with tr.span("commit", track=f"bucket {bucket}"):
                    detached = bat.commit(batch, result, self.manager, t1)
                    for sess in detached:
                        self.registry.release(sess.scene_id)
                    self._m_finished.inc(len(detached))
                    counts = np.asarray(batch.counts)
                    blat = self._bucket_latency(bucket)
                    for i in range(len(batch.sids)):
                        lats = [t1 - t
                                for t in batch.enq_times[i][:counts[i]]]
                        self._m_latency.observe_many(lats)
                        blat.observe_many(lats)
                    self._observe(result)      # counts busy rounds
                    if self.scfg.sim_latency:
                        self._record_sim(batch, result)
                    self.admission.record_service(bucket,
                                                  batch.active_frames)
                    self._m_frames.inc(batch.active_frames)
                    self._m_cap_frames.inc(bat.slots * self.scfg.chunk)
                    total_frames += batch.active_frames
                    ids = [i for i in batch.scene_ids if i is not None]
                    scene_ids_served.extend(ids)
                    group_infos.append({
                        "scene_bucket": bucket,
                        "frames": batch.active_frames,
                        "bound_slots": batch.bound_slots,
                        "slots": bat.slots,
                        "scene_ids": ids, "detached": len(detached)})
            self._m_render_s.inc(t1 - t0)
        info = {"round": rnd, "frames": total_frames,
                "bound_slots": sum(g["bound_slots"] for g in group_infos),
                "groups": group_infos,
                "scene_ids": scene_ids_served,
                "capacity": self.capacity,
                "render_seconds": round(t1 - t0, 4),
                "detached": sum(g["detached"] for g in group_infos)}
        if len(group_infos) == 1:
            # Single-group rounds keep the legacy flat fields.
            info["scene_bucket"] = group_infos[0]["scene_bucket"]
            info["slots"] = group_infos[0]["slots"]
        self._push_round(info)
        return info

    def run(self, traffic=None, max_rounds: int = 1000) -> dict:
        """Serve until traffic is drained (or ``max_rounds``); report.

        ``traffic`` is anything with the ``arrivals()``/``done``
        protocol (``PoissonTraffic``, ``ReplayTraffic``). Arrivals the
        admission controller defers (backpressure) are retried next
        round, not dropped."""
        deferred: List[Tuple[np.ndarray, int]] = []
        while self.rounds < max_rounds:
            if traffic is not None:
                offered = deferred + traffic.arrivals()
                deferred = []
                for poses, scene_idx in offered:
                    sess = self.try_attach(
                        poses, scene_id=self.scene_for_index(scene_idx))
                    if sess is None:
                        deferred.append((poses, scene_idx))
            if (traffic is None or traffic.done) and not deferred \
                    and not self.manager.sessions:
                break
            self.step()
        return self.report()

    # -- metrics -----------------------------------------------------------
    @staticmethod
    def _pct_ms(lat: np.ndarray, q: float) -> Optional[float]:
        return round(1e3 * float(np.percentile(lat, q)), 3) \
            if lat.size else None

    def _per_bucket_report(self) -> dict:
        """Per-scene-bucket fairness split: latency percentiles over the
        bucket's own reservoir (the labeled ``serve_latency_seconds``
        histogram family) next to the admission controller's wait/share
        accounting. Buckets that never rendered a frame report None
        percentiles — never NaN, never raise."""
        adm = self.admission
        shares = adm.shares()
        buckets = (set(adm.demand_rounds) | set(adm.frames_served)
                   | set(self._batchers))
        out = {}
        for b in sorted(buckets):
            lat = np.asarray(self._bucket_latency(b).values())
            bat = self._batchers.get(b)
            out[str(b)] = {
                "frames": adm.frames_served.get(b, 0),
                "latency_p50_ms": self._pct_ms(lat, 50),
                "latency_p99_ms": self._pct_ms(lat, 99),
                "max_wait_rounds": adm.max_wait.get(b, 0),
                "demand_rounds": adm.demand_rounds.get(b, 0),
                "served_rounds": adm.served_rounds.get(b, 0),
                "share": round(shares.get(b, 1.0), 4),
                "slots": bat.slots if bat is not None else None,
            }
        return out

    def _publish_residency(self) -> None:
        """Refresh the scene-residency gauges from the registry (gauges
        are last-written, so report() re-publishing keeps them honest
        after register/evict churn)."""
        for b, r in self.registry.residency().items():
            for field in ("scenes", "padded_bytes", "refs"):
                self.metrics.gauge(
                    f"scene_residency_{field}",
                    f"per-bucket resident-scene {field}",
                    bucket=str(b)).set(r[field])

    def report(self) -> dict:
        lat = np.asarray(self._m_latency.values())
        frames = int(self.active_slot_frames)
        meshes = [m for m in self._meshes.values() if m is not None]
        self._publish_residency()
        adm = self.admission.report()
        fairness = {k: adm[k] for k in
                    ("mode", "jain_service", "max_wait_rounds",
                     "max_wait_rounds_config", "deferred")}
        return {
            "streams_served": self.streams_seen,
            "streams_finished": self.streams_finished,
            "max_concurrent": self.max_concurrent,
            "frames": frames,
            "rounds": self.rounds,
            "busy_rounds": self.busy_rounds,
            "latency_p50_ms": self._pct_ms(lat, 50),
            "latency_p99_ms": self._pct_ms(lat, 99),
            "frames_per_second": round(frames / self.render_seconds, 2)
            if self.render_seconds > 0 else None,
            "slot_utilization": round(frames / self.capacity_frames, 4)
            if self.capacity_frames else 0.0,
            "capacity": self.capacity,
            "capacity_history": list(self.capacity_history),
            "slots": max((bat.slots for bat in self._batchers.values()),
                         default=self.scfg.slot_buckets[0]),
            "slots_history": list(self.slots_history),
            "scenes": self.registry.stats(),
            "fairness": fairness,
            "per_bucket": self._per_bucket_report(),
            "sim": self._sim_report(),
            "warmup_seconds": round(self.warmup_seconds, 3),
            # One composed snapshot of the shared registry (counters,
            # gauges, histograms) — the obs contract's single source of
            # truth; everything above is a view over the same numbers.
            "metrics": self.metrics.snapshot(),
            "rounds_trace": list(self.trace),
            "rounds_trace_dropped": int(self._m_trace_drop.value),
            "cache_log": [{"event": ev, "key": list(map(str, key))}
                          for ev, key in self.cache.log],
            "num_devices": max((int(m.size) for m in meshes), default=1),
            "cache": self.cache.stats(),
        }
