"""The serve loop: sessions -> batcher -> cached executable -> metrics.

One ``StreamServer.step()`` is a serving round: admit waiting streams to
free slots, pack up to ``chunk`` pending poses per stream into the fixed
(B, chunk) batch, render it through the executable for the CURRENT
R bucket (built lazily by the ``ExecutableCache``; sharded across
devices when ``placement.stream_mesh`` finds a usable mesh), then commit
carries back and stamp per-frame latencies (enqueue -> round end, wall
clock).

Capacity is workload-predictive: the server keeps a rolling history of
per-frame re-render demand from the rendered ``FrameRecord``s (real,
non-padding frames only) and every ``adapt_every`` rounds re-picks the
R bucket via ``cache.suggest_capacity``. Switching buckets changes the
cache key — with 2-3 buckets the total number of distinct compilations
stays bounded no matter how long the server runs, which is the point of
bucketing (asserted in benchmarks/serve_bench.py).

``PoissonTraffic`` drives benchmarks and tests: streams arrive per round
with Poisson counts, each carrying a heterogeneous trajectory
(dolly/orbit, randomized geometry and length) over the one shared scene.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, List, Optional, Tuple

import jax
import numpy as np

from repro.core.camera import Camera
from repro.core.pipeline import RenderConfig
from repro.scenes.trajectory import dolly_trajectory, orbit_trajectory
from repro.serve.batcher import ContinuousBatcher
from repro.core.plan import rerender_demand
from repro.serve.cache import (ExecutableCache, pick_capacity,
                               validate_buckets)
from repro.serve.placement import build_render_fn, stream_mesh
from repro.serve.session import SessionManager, StreamSession


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    slots: int = 8              # B: stream slots per batch
    chunk: int = 4              # F: frames per stream per round
    r_buckets: Tuple[int, ...] = (8, 16, 32)
    quantile: float = 0.9       # demand quantile for capacity selection
    adapt_every: int = 4        # rounds between capacity re-evaluation
    history: int = 4096         # demand samples kept for the quantile
    use_sharding: bool = True   # shard slots over devices when possible

    def __post_init__(self):
        validate_buckets(self.r_buckets)


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    n_streams: int = 12         # total arrivals over the run
    rate: float = 2.0           # mean arrivals per round (Poisson)
    min_frames: int = 6
    max_frames: int = 16
    seed: int = 0


class PoissonTraffic:
    """Poisson arrivals of heterogeneous trajectories over one scene."""

    def __init__(self, cfg: TrafficConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.remaining = int(cfg.n_streams)

    @property
    def done(self) -> bool:
        return self.remaining <= 0

    def _trajectory(self) -> np.ndarray:
        c = self.cfg
        n = int(self.rng.integers(c.min_frames, c.max_frames + 1))
        if self.rng.random() < 0.5:
            dx, dy = self.rng.uniform(-0.4, 0.4), self.rng.uniform(-0.4, 0.1)
            return np.asarray(dolly_trajectory(
                n, start=(dx, dy, self.rng.uniform(-3.0, -1.5)),
                target=(0.0, 0.0, 6.0)))
        return np.asarray(orbit_trajectory(
            n, radius=self.rng.uniform(5.0, 8.0), target=(0.0, 0.0, 6.0),
            height=self.rng.uniform(-1.0, 0.0)))

    def arrivals(self) -> List[np.ndarray]:
        if self.done:
            return []
        k = int(min(self.rng.poisson(self.cfg.rate), self.remaining))
        self.remaining -= k
        return [self._trajectory() for _ in range(k)]


class StreamServer:
    """Continuous-batching stream server over one scene (module docstring)."""

    TRACE_KEEP = 1024     # most recent per-round dicts kept for report()
    LATENCY_KEEP = 65536  # most recent per-frame latency samples kept

    def __init__(self, scene, cam: Camera, base_cfg: RenderConfig,
                 scfg: ServeConfig = ServeConfig()):
        self.scene = scene
        self.cam = cam
        self.base_cfg = base_cfg
        self.scfg = scfg
        self.manager = SessionManager(base_cfg.window)
        self.batcher = ContinuousBatcher(scfg.slots, scfg.chunk, cam)
        self.cache = ExecutableCache()
        self.mesh = stream_mesh(scfg.slots) if scfg.use_sharding else None
        self.capacity = int(scfg.r_buckets[0])
        self.capacity_history: List[int] = [self.capacity]
        self.streams_seen = 0
        self.streams_finished = 0
        # Bounded recent-latency reservoir: exact counters above stay
        # lifetime-accurate, percentiles are over the newest samples —
        # finished StreamSession objects are NOT retained (a churning
        # server would otherwise grow memory without bound).
        self._latencies: Deque[float] = deque(maxlen=self.LATENCY_KEEP)
        self.rounds = 0
        self.busy_rounds = 0
        self.active_slot_frames = 0
        self.render_seconds = 0.0
        self.warmup_seconds = 0.0
        self.max_concurrent = 0
        self.trace: Deque[dict] = deque(maxlen=self.TRACE_KEEP)
        # Rolling per-sparse-frame demand samples (flat ints — all the
        # capacity picker needs), newest last.
        self._demand: Deque[int] = deque(maxlen=scfg.history)

    # -- lifecycle ---------------------------------------------------------
    def clock(self) -> float:
        return time.perf_counter()

    def attach(self, poses, now: Optional[float] = None) -> StreamSession:
        sess = self.manager.attach(
            poses, now=self.clock() if now is None else now)
        self.streams_seen += 1
        return sess

    # -- executable selection ----------------------------------------------
    def _key_for(self, r: int):
        # impl is part of the key: a kernel-path change (e.g. pallas_fused
        # vs jnp_chunked) is a distinct XLA executable, and a server
        # reconfigured across backends must not serve a stale cache entry.
        return (self.scfg.slots, self.scfg.chunk, int(r),
                self.base_cfg.window, self.base_cfg.impl)

    def _build_for(self, r: int):
        cfg = dataclasses.replace(self.base_cfg, rerender_capacity=int(r))
        return build_render_fn(self.cam, cfg, self.mesh)

    def _executable(self):
        r = self.capacity
        return self.cache.get(self._key_for(r), lambda: self._build_for(r))

    def warmup(self) -> float:
        """Compile every bucket's executable before taking traffic.

        Runs each bucket once on an all-masked (count-0) batch so jit
        compile cost lands here instead of inside the first serving
        rounds' latencies. Returns wall seconds spent. Optional — an
        unwarmed server lazily compiles (at most) one executable per
        bucket on first use, it just bills that to the unlucky round.
        Safe mid-serving: the warmup batch is synthesized from scratch
        (``empty_batch``), never popping bound sessions' poses.
        """
        t0 = self.clock()
        batch = self.batcher.empty_batch()
        for r in self.scfg.r_buckets:
            fn = self.cache.get(self._key_for(r),
                                lambda r=r: self._build_for(r))
            jax.block_until_ready(fn(self.scene, batch.poses, batch.counts,
                                     batch.phases, batch.carries).frames)
        self.warmup_seconds = self.clock() - t0
        return self.warmup_seconds

    def _observe(self, result) -> None:
        """Fold the round's records into the demand history; re-pick R.

        Only real (non-padding) sparse frames contribute demand samples
        — ``plan.rerender_demand`` per frame, the same statistic
        ``cache.suggest_capacity`` computes from raw records. The adapt
        cadence counts BUSY rounds (this method only runs on those), so
        traffic gaps never starve adaptation.
        """
        recs = result.records
        mask = np.asarray(result.frame_active).reshape(-1)
        sparse = mask & ~np.asarray(recs.is_full).reshape(-1)
        if sparse.any():
            demand = np.asarray(rerender_demand(
                recs.active, recs.overflow_tiles)).reshape(-1)
            self._demand.extend(demand[sparse].tolist())
        if self._demand and self.busy_rounds % self.scfg.adapt_every == 0:
            new_cap = pick_capacity(list(self._demand), self.scfg.quantile,
                                    self.scfg.r_buckets)
            if new_cap != self.capacity:
                self.capacity = new_cap
                self.capacity_history.append(new_cap)

    # -- the serving round -------------------------------------------------
    def step(self) -> dict:
        self.rounds += 1
        self.batcher.admit(self.manager)
        self.max_concurrent = max(self.max_concurrent, self.batcher.bound)
        batch = self.batcher.build(self.manager)
        if batch.active_frames == 0:
            info = {"round": self.rounds, "frames": 0,
                    "bound_slots": self.batcher.bound,
                    "capacity": self.capacity}
            self.trace.append(info)
            return info
        fn = self._executable()
        t0 = self.clock()
        result = fn(self.scene, batch.poses, batch.counts, batch.phases,
                    batch.carries)
        jax.block_until_ready((result.frames, result.carries))
        t1 = self.clock()
        detached = self.batcher.commit(batch, result, self.manager, t1)
        self.streams_finished += len(detached)
        counts = np.asarray(batch.counts)
        for i in range(len(batch.sids)):
            self._latencies.extend(
                t1 - t for t in batch.enq_times[i][:counts[i]])
        self.busy_rounds += 1          # before _observe: its adapt cadence
        self._observe(result)          # counts busy rounds
        self.active_slot_frames += batch.active_frames
        self.render_seconds += t1 - t0
        info = {"round": self.rounds, "frames": batch.active_frames,
                "bound_slots": sum(s is not None for s in batch.sids),
                "capacity": self.capacity,
                "render_seconds": round(t1 - t0, 4),
                "detached": len(detached)}
        self.trace.append(info)
        return info

    def run(self, traffic: Optional[PoissonTraffic] = None,
            max_rounds: int = 1000) -> dict:
        """Serve until traffic is drained (or ``max_rounds``); report."""
        while self.rounds < max_rounds:
            if traffic is not None:
                for poses in traffic.arrivals():
                    self.attach(poses)
            if (traffic is None or traffic.done) and not self.manager.sessions:
                break
            self.step()
        return self.report()

    # -- metrics -----------------------------------------------------------
    def report(self) -> dict:
        lat = np.asarray(self._latencies)
        frames = int(self.active_slot_frames)
        cap_frames = self.busy_rounds * self.scfg.slots * self.scfg.chunk
        return {
            "streams_served": self.streams_seen,
            "streams_finished": self.streams_finished,
            "max_concurrent": self.max_concurrent,
            "frames": frames,
            "rounds": self.rounds,
            "busy_rounds": self.busy_rounds,
            "latency_p50_ms": round(1e3 * float(np.percentile(lat, 50)), 3)
            if lat.size else None,
            "latency_p99_ms": round(1e3 * float(np.percentile(lat, 99)), 3)
            if lat.size else None,
            "frames_per_second": round(frames / self.render_seconds, 2)
            if self.render_seconds > 0 else None,
            "slot_utilization": round(self.active_slot_frames / cap_frames,
                                      4) if cap_frames else 0.0,
            "capacity": self.capacity,
            "capacity_history": list(self.capacity_history),
            "warmup_seconds": round(self.warmup_seconds, 3),
            "rounds_trace": list(self.trace),
            "cache_log": [{"event": ev, "key": list(map(str, key))}
                          for ev, key in self.cache.log],
            "num_devices": int(self.mesh.size) if self.mesh is not None
            else 1,
            "cache": self.cache.stats(),
        }
