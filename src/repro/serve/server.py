"""The serve loop: scenes + sessions -> batcher -> cached executable.

One ``StreamServer.step()`` is a serving round: pick the round's *scene
bucket* (drain the in-flight bucket before switching — all streams in
one batch must share a padded-N bucket so their scenes stack), resize
the slot batch to the B bucket covering that bucket's queue depth
(elastic B — carries live on sessions, so resizes drop nothing), admit
waiting streams of that bucket to free slots (same-scene streams packed
into contiguous groups), pack up to ``chunk`` pending poses per stream
into the (B, chunk) batch, render it through the executable for the
CURRENT ``(scene_bucket, B, R)`` key (built lazily by the
``ExecutableCache``; sharded across devices when ``placement.stream_mesh``
finds a usable mesh), then commit carries back and stamp per-frame
latencies (enqueue -> round end, wall clock).

Scenes come from a ``SceneRegistry`` (serve/scenes.py): pass one with
scenes pre-registered, or pass a bare ``GaussianScene`` and the server
registers it as the single default scene (the PR-3 single-scene server
is exactly this degenerate case). Sessions are keyed by ``scene_id``;
each round's distinct scenes are stacked ``(B, N_bucket, ...)`` and the
engine gathers per slot (``slot_scene``), so any mix of same-bucket
scenes rides ONE executable — the cache key is
``(scene_bucket, B, chunk, R, window, impl)`` and never names a scene.

Both serving shapes are workload-adaptive through ``cache.BucketPolicy``:
R re-picks every ``adapt_every`` busy rounds from a rolling history of
recorded re-render demand, B re-snaps every round from queue depth.
With 2-3 buckets per axis the distinct compilations stay bounded by
``policy.max_keys`` per scene bucket no matter how long the server runs
(asserted in benchmarks/serve_bench.py).

``sim_latency=True`` closes the loop with the paper's accelerator model:
every rendered frame's ``FrameRecord`` (with its recorded device-LDU
schedule) is folded into a bounded trace and ``report()`` replays it
through ``core/streaming.simulate_sequence(policy="recorded")`` — so
serve_bench.json shows the simulated ASIC cycles next to the wall-clock
latencies for the very frames this process served.

``PoissonTraffic`` drives benchmarks and tests: streams arrive per round
with Poisson counts, each carrying a heterogeneous trajectory
(dolly/orbit, randomized geometry and length), round-robined over
``TrafficConfig.scenes`` scene indices.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple, Union

import jax
import numpy as np

from repro.core.camera import Camera
from repro.core.gaussians import GaussianScene
from repro.core.pipeline import RenderConfig, StackedRecords
from repro.core.plan import rerender_demand
from repro.core.streaming import (AcceleratorConfig, FrameWork,
                                  frameworks_from_stacked,
                                  simulate_sequence, throughput)
from repro.scenes.trajectory import dolly_trajectory, orbit_trajectory
from repro.serve.batcher import ContinuousBatcher
from repro.serve.cache import (BucketPolicy, ExecutableCache,
                               validate_buckets)
from repro.serve.placement import build_render_fn, stream_mesh
from repro.serve.scenes import DEFAULT_SCENE_BUCKETS, SceneRegistry
from repro.serve.session import SessionManager, StreamSession


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    slots: int = 8              # B: stream slots (static, if b_buckets unset)
    chunk: int = 4              # F: frames per stream per round
    r_buckets: Tuple[int, ...] = (8, 16, 32)
    # B buckets for the elastic slot batch; None = static B (`slots`).
    b_buckets: Optional[Tuple[int, ...]] = None
    quantile: float = 0.9       # demand quantile for capacity selection
    adapt_every: int = 4        # rounds between R re-evaluation
    history: int = 4096         # demand samples kept for the quantile
    use_sharding: bool = True   # shard slots over devices when possible
    scene_buckets: Tuple[int, ...] = DEFAULT_SCENE_BUCKETS
    collect_frames: bool = False  # retain rendered frames on sessions
    sim_latency: bool = False   # accelerator-in-the-loop metrics
    sim_keep: int = 4096        # most recent frames kept for the sim

    def __post_init__(self):
        validate_buckets(self.r_buckets)
        if self.b_buckets is not None:
            validate_buckets(self.b_buckets)
        validate_buckets(self.scene_buckets)

    @property
    def slot_buckets(self) -> Tuple[int, ...]:
        """The B values this server may run (static B = one bucket)."""
        return self.b_buckets if self.b_buckets is not None \
            else (self.slots,)


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    n_streams: int = 12         # total arrivals over the run
    rate: float = 2.0           # mean arrivals per round (Poisson)
    min_frames: int = 6
    max_frames: int = 16
    seed: int = 0
    scenes: int = 1             # round-robin arrivals over this many scenes


class PoissonTraffic:
    """Poisson arrivals of heterogeneous trajectories over K scenes."""

    def __init__(self, cfg: TrafficConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.remaining = int(cfg.n_streams)
        self.arrived = 0

    @property
    def done(self) -> bool:
        return self.remaining <= 0

    def _trajectory(self) -> np.ndarray:
        c = self.cfg
        n = int(self.rng.integers(c.min_frames, c.max_frames + 1))
        if self.rng.random() < 0.5:
            dx, dy = self.rng.uniform(-0.4, 0.4), self.rng.uniform(-0.4, 0.1)
            return np.asarray(dolly_trajectory(
                n, start=(dx, dy, self.rng.uniform(-3.0, -1.5)),
                target=(0.0, 0.0, 6.0)))
        return np.asarray(orbit_trajectory(
            n, radius=self.rng.uniform(5.0, 8.0), target=(0.0, 0.0, 6.0),
            height=self.rng.uniform(-1.0, 0.0)))

    def arrivals(self) -> List[Tuple[np.ndarray, int]]:
        """This round's ``(poses, scene_index)`` arrivals; scene_index
        round-robins over ``cfg.scenes`` (the server maps it onto its
        registered scene ids)."""
        if self.done:
            return []
        k = int(min(self.rng.poisson(self.cfg.rate), self.remaining))
        self.remaining -= k
        out = []
        for _ in range(k):
            out.append((self._trajectory(),
                        self.arrived % max(self.cfg.scenes, 1)))
            self.arrived += 1
        return out


class StreamServer:
    """Multi-scene continuous-batching stream server (module docstring)."""

    TRACE_KEEP = 1024     # most recent per-round dicts kept for report()
    LATENCY_KEEP = 65536  # most recent per-frame latency samples kept
    STACK_KEEP = 8        # memoized per-round scene stacks

    def __init__(self, scene: Union[GaussianScene, SceneRegistry],
                 cam: Camera, base_cfg: RenderConfig,
                 scfg: ServeConfig = ServeConfig()):
        if isinstance(scene, SceneRegistry):
            self.registry = scene
            if not len(self.registry):
                raise ValueError("SceneRegistry has no scenes registered")
        else:
            self.registry = SceneRegistry(scfg.scene_buckets)
            self.registry.register(scene)
        self.cam = cam
        self.base_cfg = base_cfg
        self.scfg = scfg
        self.policy = BucketPolicy(b_buckets=scfg.slot_buckets,
                                   r_buckets=scfg.r_buckets,
                                   quantile=scfg.quantile)
        self.manager = SessionManager(base_cfg.window)
        self._meshes: Dict[int, object] = {}
        b0 = scfg.slot_buckets[0]
        self.batcher = ContinuousBatcher(
            b0, scfg.chunk, cam, group=self._group_for(b0),
            collect_frames=scfg.collect_frames)
        self.cache = ExecutableCache()
        self.capacity = int(scfg.r_buckets[0])
        self.capacity_history: List[int] = [self.capacity]
        self.slots_history: List[int] = [b0]
        self.streams_seen = 0
        self.streams_finished = 0
        # Bounded recent-latency reservoir: exact counters above stay
        # lifetime-accurate, percentiles are over the newest samples —
        # finished StreamSession objects are NOT retained (a churning
        # server would otherwise grow memory without bound).
        self._latencies: Deque[float] = deque(maxlen=self.LATENCY_KEEP)
        self.rounds = 0
        self.busy_rounds = 0
        self.active_slot_frames = 0
        self.capacity_frames = 0       # sum of B*chunk over busy rounds
        self.render_seconds = 0.0
        self.warmup_seconds = 0.0
        self.max_concurrent = 0
        self.trace: Deque[dict] = deque(maxlen=self.TRACE_KEEP)
        # Rolling per-sparse-frame demand samples (flat ints — all the
        # capacity picker needs), newest last.
        self._demand: Deque[int] = deque(maxlen=scfg.history)
        # Accelerator-in-the-loop trace: per-round device-side records
        # in service order (host conversion is deferred to report() so
        # the serving rounds never pay record transfers), bounded like
        # the latency reservoir.
        self._sim_rounds: Deque[tuple] = deque(
            maxlen=max(1, scfg.sim_keep // max(scfg.chunk, 1)))
        self._sim_dropped = 0
        self._stacks: Dict[tuple, object] = {}

    # -- scenes ------------------------------------------------------------
    @property
    def default_scene_id(self) -> int:
        return self.registry.ids()[0]

    def register_scene(self, scene: GaussianScene):
        """Admit a new scene mid-serving; invalidates memoized stacks."""
        entry = self.registry.register(scene, now=self.clock())
        self._stacks.clear()
        return entry

    def evict_scene(self, scene_id: int):
        """Evict a drained scene (raises while streams are attached)."""
        entry = self.registry.evict(scene_id)
        self._stacks.clear()
        return entry

    def scene_for_index(self, idx: int) -> int:
        """Traffic scene index -> registered scene id (round-robin)."""
        ids = self.registry.ids()
        return ids[idx % len(ids)]

    # -- lifecycle ---------------------------------------------------------
    def clock(self) -> float:
        return time.perf_counter()

    def attach(self, poses, now: Optional[float] = None,
               scene_id: Optional[int] = None) -> StreamSession:
        sid = self.default_scene_id if scene_id is None else scene_id
        self.registry.get(sid)         # raises on unknown scene
        sess = self.manager.attach(
            poses, now=self.clock() if now is None else now, scene_id=sid)
        self.registry.acquire(sid)     # pin only once the attach stuck
        self.streams_seen += 1
        return sess

    def detach(self, sid: int) -> StreamSession:
        """Cancel a stream mid-flight: remove its session AND release its
        scene pin. Server-attached streams must be cancelled here, not
        via ``manager.detach`` directly — the manager knows nothing of
        the registry, so a direct detach would leave ``entry.refs``
        pinned forever and block ``evict_scene``. (The batcher reclaims
        the cancelled stream's slot on the next round.)"""
        sess = self.manager.detach(sid)
        self.registry.release(sess.scene_id)
        return sess

    # -- executable selection ----------------------------------------------
    def _key_for(self, bucket, b: int, r: int):
        # scene_bucket is the (padded N, sh K) shape signature; impl is
        # the raster kernel path (DESIGN.md §9) — both change the
        # lowering, and a server serving many scenes or reconfigured
        # across backends must never reuse a stale executable.
        return (bucket, int(b), self.scfg.chunk, int(r),
                self.base_cfg.window, self.base_cfg.impl)

    def _mesh_for(self, b: int):
        if not self.scfg.use_sharding:
            return None
        if b not in self._meshes:
            self._meshes[b] = stream_mesh(b)
        return self._meshes[b]

    def _group_for(self, b: int) -> int:
        mesh = self._mesh_for(b)
        return b // int(mesh.size) if mesh is not None else b

    def _build_for(self, b: int, r: int):
        cfg = dataclasses.replace(self.base_cfg, rerender_capacity=int(r))
        return build_render_fn(self.cam, cfg, self._mesh_for(b),
                               multi_scene=True)

    def _executable(self, bucket):
        b, r = self.batcher.slots, self.capacity
        return self.cache.get(self._key_for(bucket, b, r),
                              lambda: self._build_for(b, r))

    def _stack_for(self, scene_ids: Tuple[Optional[int], ...],
                   bucket, size: int):
        """Round's stacked (size, N_bucket, ...) scenes, memoized while
        the bound scene set is stable across rounds."""
        ids = tuple(self.default_scene_id if i is None else i
                    for i in scene_ids)
        if not ids:
            ids = (self.registry.by_bucket(bucket)[0],)
        key = (ids, int(size))
        if key not in self._stacks:
            if len(self._stacks) >= self.STACK_KEEP:
                self._stacks.pop(next(iter(self._stacks)))
            self._stacks[key] = self.registry.stack(ids, size)
        return self._stacks[key]

    def warmup(self) -> float:
        """Compile every (scene_bucket, B, R) executable before traffic.

        Runs each combination once on an all-masked (count-0) batch so
        jit compile cost lands here instead of inside the first serving
        rounds' latencies. Returns wall seconds spent. Optional — an
        unwarmed server lazily compiles (at most) one executable per key
        on first use, it just bills that to the unlucky round. Safe
        mid-serving: the warmup batch is synthesized from scratch
        (``empty_batch``), never popping bound sessions' poses.
        """
        t0 = self.clock()
        for bucket in self.registry.buckets_in_use():
            scenes_one = (self.registry.by_bucket(bucket)[0],)
            for b in self.policy.b_buckets:
                batch = self.batcher.empty_batch(slots=b)
                scenes = self._stack_for(scenes_one, bucket, b)
                for r in self.policy.r_buckets:
                    fn = self.cache.get(
                        self._key_for(bucket, b, r),
                        lambda b=b, r=r: self._build_for(b, r))
                    jax.block_until_ready(fn(
                        scenes, batch.poses, batch.counts, batch.phases,
                        batch.carries, batch.slot_scene).frames)
        self.warmup_seconds = self.clock() - t0
        return self.warmup_seconds

    # -- adaptive shapes ---------------------------------------------------
    def _bucket_of(self, sess: StreamSession) -> Tuple[int, int]:
        sid = self.default_scene_id if sess.scene_id is None \
            else sess.scene_id
        return self.registry.bucket_of(sid)

    def _round_bucket(self) -> Optional[Tuple[int, int]]:
        """The scene bucket this round serves: the in-flight bucket while
        any session is bound (a batch can only stack same-bucket
        scenes), else the oldest waiting session's bucket. None = no
        work anywhere."""
        for sid in self.batcher.bound_sids():
            sess = self.manager.sessions.get(sid)
            if sess is not None:
                return self._bucket_of(sess)
        waiting = self.manager.waiting()
        if waiting:
            return self._bucket_of(waiting[0])
        return None

    def _queue_depth(self, bucket) -> int:
        """Streams of this bucket that currently want service: bound, or
        waiting with pending poses."""
        return sum(1 for s in self.manager.sessions.values()
                   if (s.slot is not None or s.pending)
                   and self._bucket_of(s) == bucket)

    def _maybe_resize(self, bucket) -> None:
        """Snap B to the bucket covering queue depth (elastic B). The
        batcher resize unbinds overflow sessions on shrink — carries
        stay on the sessions, so the resize drops nothing."""
        if self.scfg.b_buckets is None:
            return
        b = self.policy.pick_slots(self._queue_depth(bucket))
        if b != self.batcher.slots:
            self.batcher.resize(b, self.manager, group=self._group_for(b))
            self.slots_history.append(b)

    def _observe(self, result) -> None:
        """Fold the round's records into the demand history; re-pick R.

        Only real (non-padding) sparse frames contribute demand samples
        — ``plan.rerender_demand`` per frame, the same statistic
        ``cache.suggest_capacity`` computes from raw records. The adapt
        cadence counts BUSY rounds (this method only runs on those), so
        traffic gaps never starve adaptation.
        """
        recs = result.records
        mask = np.asarray(result.frame_active).reshape(-1)
        sparse = mask & ~np.asarray(recs.is_full).reshape(-1)
        if sparse.any():
            demand = np.asarray(rerender_demand(
                recs.active, recs.overflow_tiles)).reshape(-1)
            self._demand.extend(demand[sparse].tolist())
        if self._demand and self.busy_rounds % self.scfg.adapt_every == 0:
            new_cap = self.policy.pick_capacity(list(self._demand))
            if new_cap != self.capacity:
                self.capacity = new_cap
                self.capacity_history.append(new_cap)

    # -- accelerator-in-the-loop -------------------------------------------
    def _record_sim(self, batch, result) -> None:
        """Stash the round's stacked records (device references — ONE
        deque append, no host transfer on the serving path; the
        FrameWork conversion is deferred to ``_sim_report`` so recording
        never inflates the wall-clock latencies being measured)."""
        counts = np.asarray(batch.counts)
        active = tuple(s is not None and counts[i] > 0
                       for i, s in enumerate(batch.sids))
        if self._sim_rounds.maxlen and \
                len(self._sim_rounds) == self._sim_rounds.maxlen:
            _, old_counts, old_active = self._sim_rounds[0]
            self._sim_dropped += int(sum(
                c for c, a in zip(old_counts, old_active) if a))
        self._sim_rounds.append((result.records.stacked, counts, active))

    def _sim_frameworks(self) -> List[FrameWork]:
        """Host-convert the stashed rounds into per-frame FrameWorks,
        service order (round-major, slot order within a round)."""
        frames: List[FrameWork] = []
        n_px = self.cam.height * self.cam.width
        for stacked, counts, active in self._sim_rounds:
            for i, on in enumerate(active):
                if not on:
                    continue
                recs = jax.tree_util.tree_map(lambda a, i=i: a[i], stacked)
                frames.extend(frameworks_from_stacked(
                    StackedRecords(recs), self.cam.tiles_x,
                    self.cam.tiles_y, n_px)[:counts[i]])
        # The round deque bounds memory; this bounds the sim itself.
        return frames[-self.scfg.sim_keep:]

    def _sim_report(self) -> Optional[dict]:
        """Replay the served frames through the accelerator model —
        simulated ASIC cycles for the exact schedules the jitted engine
        recorded (policy="recorded", streaming pipeline on)."""
        frames = self._sim_frameworks()
        if not frames:
            return None
        acfg = AcceleratorConfig(num_blocks=self.base_cfg.ldu_blocks)
        timings = simulate_sequence(frames, acfg, policy="recorded",
                                    streaming=True)
        agg = throughput(timings, acfg.num_blocks)
        # Per-frame service latency in the streaming pipeline: the gap
        # this frame adds to the completion front (frame_end is
        # monotone; overlapped frames add less than their span).
        ends = np.asarray([t.frame_end for t in timings])
        service = np.diff(ends, prepend=0.0)
        return {
            "frames": len(frames),
            "frames_dropped": self._sim_dropped,
            "cycles_per_frame": round(float(agg["cycles_per_frame"]), 1),
            "utilization": round(float(agg["utilization"]), 4),
            "sort_stall_cycles": round(float(agg["sort_stall"]), 1),
            "latency_p50_cycles": round(float(np.percentile(service, 50)),
                                        1),
            "latency_p99_cycles": round(float(np.percentile(service, 99)),
                                        1),
        }

    # -- the serving round -------------------------------------------------
    def step(self) -> dict:
        self.rounds += 1
        bucket = self._round_bucket()
        if bucket is None:
            info = {"round": self.rounds, "frames": 0, "bound_slots": 0,
                    "slots": self.batcher.slots, "capacity": self.capacity}
            self.trace.append(info)
            return info
        self._maybe_resize(bucket)
        self.batcher.admit(self.manager,
                           allowed=set(self.registry.by_bucket(bucket)))
        self.max_concurrent = max(self.max_concurrent, self.batcher.bound)
        batch = self.batcher.build(self.manager)
        if batch.active_frames == 0:
            info = {"round": self.rounds, "frames": 0,
                    "bound_slots": self.batcher.bound,
                    "slots": self.batcher.slots,
                    "capacity": self.capacity}
            self.trace.append(info)
            return info
        scenes = self._stack_for(batch.scene_ids, bucket,
                                 self.batcher.slots)
        fn = self._executable(bucket)
        t0 = self.clock()
        result = fn(scenes, batch.poses, batch.counts, batch.phases,
                    batch.carries, batch.slot_scene)
        jax.block_until_ready((result.frames, result.carries))
        t1 = self.clock()
        detached = self.batcher.commit(batch, result, self.manager, t1)
        for sess in detached:
            self.registry.release(sess.scene_id)
        self.streams_finished += len(detached)
        counts = np.asarray(batch.counts)
        for i in range(len(batch.sids)):
            self._latencies.extend(
                t1 - t for t in batch.enq_times[i][:counts[i]])
        self.busy_rounds += 1          # before _observe: its adapt cadence
        self._observe(result)          # counts busy rounds
        if self.scfg.sim_latency:
            self._record_sim(batch, result)
        self.active_slot_frames += batch.active_frames
        self.capacity_frames += self.batcher.slots * self.scfg.chunk
        self.render_seconds += t1 - t0
        info = {"round": self.rounds, "frames": batch.active_frames,
                "bound_slots": sum(s is not None for s in batch.sids),
                "slots": self.batcher.slots,
                "scene_bucket": bucket,
                "scene_ids": [i for i in batch.scene_ids if i is not None],
                "capacity": self.capacity,
                "render_seconds": round(t1 - t0, 4),
                "detached": len(detached)}
        self.trace.append(info)
        return info

    def run(self, traffic: Optional[PoissonTraffic] = None,
            max_rounds: int = 1000) -> dict:
        """Serve until traffic is drained (or ``max_rounds``); report."""
        while self.rounds < max_rounds:
            if traffic is not None:
                for poses, scene_idx in traffic.arrivals():
                    self.attach(poses,
                                scene_id=self.scene_for_index(scene_idx))
            if (traffic is None or traffic.done) and not self.manager.sessions:
                break
            self.step()
        return self.report()

    # -- metrics -----------------------------------------------------------
    def report(self) -> dict:
        lat = np.asarray(self._latencies)
        frames = int(self.active_slot_frames)
        meshes = [m for m in self._meshes.values() if m is not None]
        return {
            "streams_served": self.streams_seen,
            "streams_finished": self.streams_finished,
            "max_concurrent": self.max_concurrent,
            "frames": frames,
            "rounds": self.rounds,
            "busy_rounds": self.busy_rounds,
            "latency_p50_ms": round(1e3 * float(np.percentile(lat, 50)), 3)
            if lat.size else None,
            "latency_p99_ms": round(1e3 * float(np.percentile(lat, 99)), 3)
            if lat.size else None,
            "frames_per_second": round(frames / self.render_seconds, 2)
            if self.render_seconds > 0 else None,
            "slot_utilization": round(frames / self.capacity_frames, 4)
            if self.capacity_frames else 0.0,
            "capacity": self.capacity,
            "capacity_history": list(self.capacity_history),
            "slots": self.batcher.slots,
            "slots_history": list(self.slots_history),
            "scenes": self.registry.stats(),
            "sim": self._sim_report(),
            "warmup_seconds": round(self.warmup_seconds, 3),
            "rounds_trace": list(self.trace),
            "cache_log": [{"event": ev, "key": list(map(str, key))}
                          for ev, key in self.cache.log],
            "num_devices": max((int(m.size) for m in meshes), default=1),
            "cache": self.cache.stats(),
        }
