"""Scene registry: many scenes, bucketed Gaussian counts, shared executables.

The serve layer's target setting (DESIGN.md §10) is fleets of edge
cameras that each observe *their own* scene while sharing accelerator
capacity — so the number of Gaussians N becomes a serving-time shape,
and an unmanaged N would compile one XLA executable per scene. The
registry removes N from the compile space the same way ``cache.py``
bounds R: every registered scene is padded up to a fixed ladder of
bucket sizes (``DEFAULT_SCENE_BUCKETS``), and the executable cache keys
on the *bucket*, not the scene — any two same-bucket scenes render
through one executable, with the actual Gaussian arrays passed as traced
runtime inputs.

Padding must be exact, not approximate: a padded scene has to render
bit-identically to the original. Padding rows are therefore *invalid by
construction* — ``opacity_logit = PAD_OPACITY_LOGIT`` puts their opacity
orders of magnitude below ``projection.ALPHA_THRESHOLD``, so
``preprocess`` marks them ``valid=False`` for EVERY camera pose, every
intersection test masks them out, and they can never claim a bin lane,
a pair count, or a blend contribution (``tests/test_serve_scenes.py``
pins frames AND records bit-exact against the unpadded scene).

Entries are refcounted by attached streams (``acquire``/``release`` —
the server pins a scene for each live session) so ``evict`` can never
pull a scene out from under an in-flight stream.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.gaussians import GaussianScene
from repro.serve.cache import validate_buckets

# Pow-2 ladder: padding waste is bounded by 2x, and the distinct-
# executable family is bounded by the handful of bucket sizes a fleet's
# scenes actually span (each bucket in use is one more compile per
# (B, R) key — see server._key_for).
DEFAULT_SCENE_BUCKETS = (256, 512, 1024, 2048, 4096, 8192, 16384,
                         32768, 65536)

# sigmoid(-20) ~ 2e-9, far below projection.ALPHA_THRESHOLD (1/255):
# padding Gaussians fail the `visible` cull for every pose.
PAD_OPACITY_LOGIT = -20.0


def snap_scene_bucket(n: int, buckets: Sequence[int] = DEFAULT_SCENE_BUCKETS
                      ) -> int:
    """Smallest bucket covering ``n`` Gaussians.

    Unlike R (where the largest bucket caps demand and the excess
    degrades to interpolation), a scene cannot be truncated without
    changing its content — a scene beyond the largest bucket is an
    error, not a clamp.
    """
    validate_buckets(buckets, "scene_buckets")
    for b in buckets:
        if n <= b:
            return int(b)
    raise ValueError(
        f"scene with {n} Gaussians exceeds the largest scene bucket "
        f"{buckets[-1]}; extend the bucket ladder")


def pad_scene(scene: GaussianScene, n_bucket: int) -> GaussianScene:
    """Pad a scene to ``n_bucket`` rows with inert (never-valid) Gaussians.

    The pad rows are benign everywhere: finite math through preprocess
    (unit quaternion, unit scale, zero SH) but ``valid=False`` for every
    pose via the opacity cull — so the padded scene renders bit-identical
    to the original.
    """
    n = scene.num_gaussians
    if n_bucket < n:
        raise ValueError(f"cannot pad {n} Gaussians down to {n_bucket}")
    if n_bucket == n:
        return scene
    p = n_bucket - n
    quats = jnp.zeros((p, 4), scene.quats.dtype).at[:, 0].set(1.0)
    return GaussianScene(
        means=jnp.concatenate(
            [scene.means, jnp.zeros((p, 3), scene.means.dtype)]),
        log_scales=jnp.concatenate(
            [scene.log_scales, jnp.zeros((p, 3), scene.log_scales.dtype)]),
        quats=jnp.concatenate([scene.quats, quats]),
        opacity_logits=jnp.concatenate(
            [scene.opacity_logits,
             jnp.full((p,), PAD_OPACITY_LOGIT,
                      scene.opacity_logits.dtype)]),
        sh=jnp.concatenate(
            [scene.sh, jnp.zeros((p,) + scene.sh.shape[1:],
                                 scene.sh.dtype)]))


@dataclasses.dataclass
class SceneEntry:
    """One registered scene (already padded to its bucket).

    ``bucket`` is the scene's *stackable shape signature*
    ``(padded N, SH coefficient count K)``: two scenes stack into one
    ``(S, N, ...)`` pytree — and therefore share an executable — iff
    their buckets are equal. N alone is not enough: a degree-0 and a
    degree-1 scene have different ``sh`` shapes, which are different
    lowerings just like different N.
    """

    scene_id: int
    scene: GaussianScene        # padded: num_gaussians == bucket[0]
    true_n: int                 # Gaussians before padding
    bucket: Tuple[int, int]     # (padded N, sh K) — what the cache keys on
    registered_at: float = 0.0
    refs: int = 0               # live sessions pinned to this scene
    streams_seen: int = 0       # lifetime attach count (metrics)
    padded_bytes: int = 0       # device bytes of the padded scene arrays


def scene_bytes(scene: GaussianScene) -> int:
    """Total bytes of a scene pytree's arrays — the residency a padded
    scene actually occupies (obs gauges read this per bucket)."""
    return sum(int(a.size) * a.dtype.itemsize
               for a in jax.tree_util.tree_leaves(scene))


class SceneRegistry:
    """Register/evict scenes; group them by padded-N bucket.

    The registry is host-side bookkeeping — scene arrays live on device
    (whatever backing ``jnp.concatenate`` produced at registration) and
    are handed to the executable as traced inputs. ``stack`` builds the
    per-round ``(S, N_bucket, ...)`` stacked pytree the engine's
    ``slot_scene`` gather indexes (core/engine.py).
    """

    def __init__(self, buckets: Sequence[int] = DEFAULT_SCENE_BUCKETS):
        validate_buckets(buckets, "scene_buckets")
        self.buckets = tuple(int(b) for b in buckets)
        self._entries: Dict[int, SceneEntry] = {}
        self._next_id = 0
        self.registered = 0
        self.evicted = 0

    # -- lifecycle ---------------------------------------------------------
    def register(self, scene: GaussianScene, *,
                 now: float = 0.0) -> SceneEntry:
        n_bucket = snap_scene_bucket(scene.num_gaussians, self.buckets)
        padded = pad_scene(scene, n_bucket)
        entry = SceneEntry(scene_id=self._next_id,
                           scene=padded,
                           true_n=scene.num_gaussians,
                           bucket=(n_bucket, int(scene.sh.shape[1])),
                           registered_at=now,
                           padded_bytes=scene_bytes(padded))
        self._next_id += 1
        self._entries[entry.scene_id] = entry
        self.registered += 1
        return entry

    def evict(self, scene_id: int) -> SceneEntry:
        entry = self.get(scene_id)
        if entry.refs > 0:
            raise ValueError(
                f"scene {scene_id} has {entry.refs} attached stream(s); "
                f"drain them before evicting")
        self.evicted += 1
        return self._entries.pop(scene_id)

    def acquire(self, scene_id: int) -> None:
        entry = self.get(scene_id)
        entry.refs += 1
        entry.streams_seen += 1

    def release(self, scene_id: int) -> None:
        entry = self.get(scene_id)
        if entry.refs <= 0:
            raise ValueError(f"scene {scene_id} released more than acquired")
        entry.refs -= 1

    # -- queries -----------------------------------------------------------
    def get(self, scene_id: int) -> SceneEntry:
        if scene_id not in self._entries:
            raise KeyError(f"unknown scene {scene_id!r}; registered: "
                           f"{self.ids()}")
        return self._entries[scene_id]

    def ids(self) -> Tuple[int, ...]:
        """Registration order — what traffic round-robins over."""
        return tuple(self._entries)

    def by_bucket(self, bucket: Tuple[int, int]) -> List[int]:
        return [i for i, e in self._entries.items() if e.bucket == bucket]

    def bucket_of(self, scene_id: int) -> Tuple[int, int]:
        return self.get(scene_id).bucket

    def buckets_in_use(self) -> Tuple[Tuple[int, int], ...]:
        return tuple(sorted({e.bucket for e in self._entries.values()}))

    def __contains__(self, scene_id: int) -> bool:
        return scene_id in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    # -- device-side view --------------------------------------------------
    def stack(self, scene_ids: Sequence[int], size: int) -> GaussianScene:
        """Stacked ``(size, N_bucket, ...)`` scene pytree for one round.

        ``scene_ids`` is the round's distinct scenes (the batcher's
        local-stack order — ``SlotBatch.slot_scene`` indexes it); the
        stack is padded to ``size`` by repeating the first entry so the
        stacked shape depends only on (bucket, B), never on how many
        distinct scenes happen to be in flight — the executable-cache
        key stays ``(scene_bucket, B, ...)`` with no S axis. All ids
        must share one bucket (the server's same-bucket-per-round rule).
        """
        if not scene_ids:
            raise ValueError("stack needs at least one scene id")
        if size < len(scene_ids):
            raise ValueError(f"{len(scene_ids)} scenes do not fit a "
                             f"stack of {size}")
        entries = [self.get(i) for i in scene_ids]
        buckets = {e.bucket for e in entries}
        if len(buckets) > 1:
            raise ValueError(
                f"one round's scenes must share a bucket, got {buckets}")
        scenes = [e.scene for e in entries]
        scenes += [scenes[0]] * (size - len(scenes))
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *scenes)

    def residency(self) -> Dict[Tuple[int, int], dict]:
        """Per-bucket residency summary — scenes resident, padded bytes
        held on device, and live stream refcounts. This is what the
        server's ``scene_residency_*`` gauges publish (DESIGN.md §13)."""
        out: Dict[Tuple[int, int], dict] = {}
        for e in self._entries.values():
            r = out.setdefault(e.bucket, {"scenes": 0, "padded_bytes": 0,
                                          "refs": 0})
            r["scenes"] += 1
            r["padded_bytes"] += e.padded_bytes
            r["refs"] += e.refs
        return out

    def stats(self) -> dict:
        return {
            "scenes": len(self._entries),
            "registered": self.registered,
            "evicted": self.evicted,
            "buckets_in_use": list(self.buckets_in_use()),
            "padded_bytes": sum(e.padded_bytes
                                for e in self._entries.values()),
            "per_bucket": {str(b): r for b, r in self.residency().items()},
            "per_scene": {
                str(i): {"true_n": e.true_n, "bucket": e.bucket,
                         "refs": e.refs, "streams_seen": e.streams_seen,
                         "padded_bytes": e.padded_bytes}
                for i, e in self._entries.items()},
        }
