"""Stream sessions: attach/detach lifecycle and key-frame phase assignment.

A ``StreamSession`` is one camera stream against one scene: a queue of
pending poses (with enqueue timestamps for latency accounting), the
engine carry that resumes it mid-trajectory, the ``scene_id`` keying it
to a registry entry (``serve/scenes.py`` — None means the server's
default scene), and the key-frame ``phase`` that decides which steps
re-render fully.

Phase assignment is the churn-safe version of ``engine.stream_phases``:
that helper staggers a *static* batch evenly over ``[0, window)``; here
streams arrive and leave at arbitrary times, so the manager tracks how
many live sessions occupy each phase and hands a new stream the
least-loaded one (lowest index on ties — an empty manager therefore
deals phases 0, 1, 2, ... exactly like ``stream_phases``). Detaching
releases the phase, so long-running servers keep full renders staggered
instead of drifting into lockstep spikes.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.engine import EngineCarry

LATENCY_KEEP = 4096  # most recent per-frame latency samples per stream


@dataclasses.dataclass
class StreamSession:
    """One attached camera stream (see module docstring)."""

    sid: int
    phase: int
    pending: Deque[Tuple[np.ndarray, float]]  # (pose (4,4), enqueue time)
    attached_at: float
    scene_id: Optional[int] = None        # registry key (None = default)
    slo: Optional[str] = None             # SLO class name (None = default;
    #                                       serve/admission.py resolves it)
    carry: Optional[EngineCarry] = None   # None until the first chunk
    slot: Optional[int] = None            # batcher slot, None = waiting
    frames_rendered: int = 0
    # Rendered chunks, newest last — only populated when the batcher was
    # built with collect_frames=True (parity tests, demos); a production
    # server leaves this off so memory stays flat.
    frames: List[np.ndarray] = dataclasses.field(default_factory=list)
    # Recent per-frame latencies (bounded: a live stream never detaches,
    # so an unbounded list would grow for the life of the server).
    latencies: Deque[float] = dataclasses.field(
        default_factory=lambda: deque(maxlen=LATENCY_KEEP))
    closed: bool = False                  # no more poses will be submitted

    @property
    def done(self) -> bool:
        """Drained and closed — eligible for detach by the serve loop."""
        return self.closed and not self.pending

    def submit(self, poses, now: float) -> None:
        """Enqueue (F, 4, 4) poses stamped with ``now``."""
        if self.closed:
            raise ValueError(f"stream {self.sid} is closed")
        poses = np.asarray(poses, np.float32)
        for f in range(poses.shape[0]):
            self.pending.append((poses[f], now))


class SessionManager:
    """Attach/detach registry with phase-load-balanced key-frame offsets."""

    def __init__(self, window: int):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self.window = int(window)
        self.sessions: Dict[int, StreamSession] = {}
        self._phase_load = [0] * self.window
        self._next_sid = 0

    def _assign_phase(self) -> int:
        return int(np.argmin(self._phase_load))

    def attach(self, poses=None, *, now: float = 0.0,
               closed: bool = True,
               scene_id: Optional[int] = None,
               slo: Optional[str] = None) -> StreamSession:
        """Register a stream; optionally seed its pose queue.

        ``closed=True`` (the default) marks the trajectory complete at
        attach time — the session auto-detaches once drained. Pass
        ``closed=False`` for live streams that keep ``submit``-ing.
        ``scene_id`` keys the stream to a registry scene (None: the
        server substitutes its default scene); ``slo`` names a service
        class (serve/admission.py — None: the default class). Phase
        assignment stays scene-agnostic on purpose — the stagger
        balances *device* load and the device is shared across scenes.
        """
        sid = self._next_sid
        self._next_sid += 1
        phase = self._assign_phase()
        self._phase_load[phase] += 1
        sess = StreamSession(sid=sid, phase=phase, pending=deque(),
                             attached_at=now, scene_id=scene_id, slo=slo)
        if poses is not None:
            sess.submit(poses, now)
        if closed and not sess.pending:
            # A closed stream with nothing to render would never be
            # bound to a slot, so nothing would ever detach it.
            self._phase_load[phase] -= 1
            raise ValueError("closed stream attached without poses")
        sess.closed = closed
        self.sessions[sid] = sess
        return sess

    def detach(self, sid: int) -> StreamSession:
        sess = self.sessions.pop(sid)
        self._phase_load[sess.phase] -= 1
        return sess

    def waiting(self) -> List[StreamSession]:
        """Sessions with work but no batcher slot, oldest first."""
        return [s for s in self.sessions.values()
                if s.slot is None and s.pending]

    def by_scene(self, scene_id: Optional[int]) -> List[StreamSession]:
        """Live sessions keyed to ``scene_id``, attach order."""
        return [s for s in self.sessions.values()
                if s.scene_id == scene_id]

    def __len__(self) -> int:
        return len(self.sessions)
