"""Device placement: shard stream slots across devices, degrade to one.

``engine.render_streams`` vmaps B streams on one device; under vmap the
full/sparse ``lax.cond`` lowers to a select, so every stream pays BOTH
branches every step (the caveat in core/engine.py). ``shard_map`` over a
1-D "streams" mesh fixes both costs at once: each device renders only
its B/D local slots, and when the local shard is a single stream the
scan body keeps a genuine ``lax.cond`` — that device executes only the
branch its stream actually takes, so concurrent streams stop paying each
other's full-render branches (with B == device count, the phase stagger
finally saves device FLOPs, not just recorded workload).

Degrades gracefully: ``stream_mesh`` returns None unless >1 device can
split B evenly (it trims to the largest divisor), and ``build_render_fn``
then falls back to the plain single-device ``render_streams`` — the
serve loop never branches on topology.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import engine
from repro.core.camera import Camera
from repro.core.engine import StreamsResult
from repro.core.pipeline import RenderConfig, StackedRecords


def stream_mesh(num_slots: int, devices=None) -> Optional[Mesh]:
    """1-D "streams" mesh over the most devices that divide ``num_slots``.

    None when that is a single device — the caller should use the plain
    vmapped path.
    """
    devices = list(jax.devices() if devices is None else devices)
    d = min(len(devices), int(num_slots))
    while d > 1 and num_slots % d:
        d -= 1
    if d <= 1:
        return None
    return Mesh(np.asarray(devices[:d]), ("streams",))


def build_render_fn(cam: Camera, cfg: RenderConfig,
                    mesh: Optional[Mesh] = None):
    """``fn(scene, poses, counts, phases, carries) -> StreamsResult``.

    The uniform serving-layer entry point: with a mesh, a jitted
    shard_map of the masked stream scan (slots split over "streams",
    scene/camera replicated); without one, ``engine.render_streams``.
    One compiled executable per (B, F, cfg) either way — the serve
    cache (serve/cache.py) keys these builders by bucket.
    """
    if mesh is None:
        def fn(scene, poses, counts, phases, carries):
            return engine.render_streams(scene, cam, poses, cfg,
                                         phases=phases, counts=counts,
                                         carries=carries)
        return fn

    def local_fn(scene, poses, counts, phases, carries):
        # Shapes here are the per-device shard: (B/D, F, 4, 4) etc.
        if poses.shape[0] == 1:
            # Single local stream: skip vmap so the full/sparse
            # lax.cond stays a real branch on this device.
            squeeze = lambda t: jax.tree_util.tree_map(lambda a: a[0], t)
            carry_end, (frames, recs, active) = engine.stream_scan(
                scene, cam, poses[0], counts[0], phases[0], cfg,
                squeeze(carries))
            expand = lambda t: jax.tree_util.tree_map(
                lambda a: a[None], t)
            return (expand(carry_end), frames[None], expand(recs),
                    active[None])
        run = lambda p, c, ph, cy: engine.stream_scan(
            scene, cam, p, c, ph, cfg, cy)
        carry_end, (frames, recs, active) = jax.vmap(run)(
            poses, counts, phases, carries)
        return carry_end, frames, recs, active

    sharded = P("streams")
    smapped = jax.jit(shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(), sharded, sharded, sharded, sharded),
        out_specs=(sharded, sharded, sharded, sharded),
        check_rep=False))

    def fn(scene, poses, counts, phases, carries):
        counts = jnp.asarray(counts, jnp.int32)
        phases = jnp.asarray(phases, jnp.int32)
        carry_end, frames, recs, active = smapped(scene, poses, counts,
                                                  phases, carries)
        return StreamsResult(frames=frames, records=StackedRecords(recs),
                             phases=phases, counts=counts,
                             frame_active=active, carries=carry_end)
    return fn
