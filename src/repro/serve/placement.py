"""Device placement: shard stream slots across devices, degrade to one.

``engine.render_streams`` vmaps B streams on one device; under vmap the
full/sparse ``lax.cond`` lowers to a select, so every stream pays BOTH
branches every step (the caveat in core/engine.py). ``shard_map`` over a
1-D "streams" mesh fixes both costs at once: each device renders only
its B/D local slots, and when the local shard is a single stream the
scan body keeps a genuine ``lax.cond`` — that device executes only the
branch its stream actually takes, so concurrent streams stop paying each
other's full-render branches (with B == device count, the phase stagger
finally saves device FLOPs, not just recorded workload).

Multi-scene serving (DESIGN.md §10) adds one input: ``multi_scene=True``
builds ``fn(scenes, poses, counts, phases, carries, slot_scene)`` where
``scenes`` is a stacked ``(S, N, ...)`` pytree (replicated across the
mesh) and ``slot_scene`` is sharded with the slots — each device gathers
only its local slots' scenes from the replicated stack. This is why the
batcher packs same-scene streams into contiguous groups of B/D slots:
a device whose local slots share one scene gathers one scene's arrays,
and with local B = 1 the gather feeds a genuine per-stream ``lax.cond``
just like the single-scene path.

Degrades gracefully: ``stream_mesh`` returns None unless >1 device can
split B evenly (it trims to the largest divisor), and ``build_render_fn``
then falls back to the plain single-device ``render_streams`` — the
serve loop never branches on topology.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import engine
from repro.core.camera import Camera
from repro.core.engine import StreamsResult
from repro.core.pipeline import RenderConfig, StackedRecords


def stream_mesh(num_slots: int, devices=None) -> Optional[Mesh]:
    """1-D "streams" mesh over the most devices that divide ``num_slots``.

    None when that is a single device — the caller should use the plain
    vmapped path.
    """
    devices = list(jax.devices() if devices is None else devices)
    d = min(len(devices), int(num_slots))
    while d > 1 and num_slots % d:
        d -= 1
    if d <= 1:
        return None
    return Mesh(np.asarray(devices[:d]), ("streams",))


def build_render_fn(cam: Camera, cfg: RenderConfig,
                    mesh: Optional[Mesh] = None, *,
                    multi_scene: bool = False):
    """The uniform serving-layer entry point.

    ``multi_scene=False`` (legacy):
    ``fn(scene, poses, counts, phases, carries) -> StreamsResult``.
    ``multi_scene=True``:
    ``fn(scenes, poses, counts, phases, carries, slot_scene)`` with
    ``scenes`` stacked ``(S, N, ...)`` and ``slot_scene`` (B,) int32.

    With a mesh, a jitted shard_map of the masked stream scan (slots —
    and slot_scene — split over "streams"; scene stack and camera
    replicated); without one, ``engine.render_streams``. One compiled
    executable per (scene_bucket, B, F, cfg) either way — the serve
    cache (serve/cache.py) keys these builders by bucket.
    """
    if mesh is None:
        if multi_scene:
            def fn(scenes, poses, counts, phases, carries, slot_scene):
                return engine.render_streams(
                    scenes, cam, poses, cfg, phases=phases, counts=counts,
                    carries=carries, slot_scene=slot_scene)
        else:
            def fn(scene, poses, counts, phases, carries):
                return engine.render_streams(scene, cam, poses, cfg,
                                             phases=phases, counts=counts,
                                             carries=carries)
        return fn

    squeeze = lambda t: jax.tree_util.tree_map(lambda a: a[0], t)
    expand = lambda t: jax.tree_util.tree_map(lambda a: a[None], t)

    if multi_scene:
        def local_fn(scenes, poses, counts, phases, carries, slot_scene):
            # Shapes here are the per-device shard: (B/D, F, 4, 4) etc.;
            # `scenes` is the full replicated (S, N, ...) stack and each
            # local slot gathers its own scene from it.
            take = lambda sid: jax.tree_util.tree_map(
                lambda a: a[sid], scenes)
            if poses.shape[0] == 1:
                # Single local stream: skip vmap so the full/sparse
                # lax.cond stays a real branch on this device.
                carry_end, (frames, recs, active) = engine.stream_scan(
                    take(slot_scene[0]), cam, poses[0], counts[0],
                    phases[0], cfg, squeeze(carries))
                return (expand(carry_end), frames[None], expand(recs),
                        active[None])
            run = lambda p, c, ph, cy, sid: engine.stream_scan(
                take(sid), cam, p, c, ph, cfg, cy)
            carry_end, (frames, recs, active) = jax.vmap(run)(
                poses, counts, phases, carries, slot_scene)
            return carry_end, frames, recs, active

        sharded = P("streams")
        smapped = jax.jit(shard_map(
            local_fn, mesh=mesh,
            in_specs=(P(), sharded, sharded, sharded, sharded, sharded),
            out_specs=(sharded, sharded, sharded, sharded),
            check_rep=False))

        def fn(scenes, poses, counts, phases, carries, slot_scene):
            counts = jnp.asarray(counts, jnp.int32)
            phases = jnp.asarray(phases, jnp.int32)
            slot_scene = jnp.asarray(slot_scene, jnp.int32)
            carry_end, frames, recs, active = smapped(
                scenes, poses, counts, phases, carries, slot_scene)
            return StreamsResult(frames=frames,
                                 records=StackedRecords(recs),
                                 phases=phases, counts=counts,
                                 frame_active=active, carries=carry_end)
        return fn

    def local_fn(scene, poses, counts, phases, carries):
        # Shapes here are the per-device shard: (B/D, F, 4, 4) etc.
        if poses.shape[0] == 1:
            # Single local stream: skip vmap so the full/sparse
            # lax.cond stays a real branch on this device.
            carry_end, (frames, recs, active) = engine.stream_scan(
                scene, cam, poses[0], counts[0], phases[0], cfg,
                squeeze(carries))
            return (expand(carry_end), frames[None], expand(recs),
                    active[None])
        run = lambda p, c, ph, cy: engine.stream_scan(
            scene, cam, p, c, ph, cfg, cy)
        carry_end, (frames, recs, active) = jax.vmap(run)(
            poses, counts, phases, carries)
        return carry_end, frames, recs, active

    sharded = P("streams")
    smapped = jax.jit(shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(), sharded, sharded, sharded, sharded),
        out_specs=(sharded, sharded, sharded, sharded),
        check_rep=False))

    def fn(scene, poses, counts, phases, carries):
        counts = jnp.asarray(counts, jnp.int32)
        phases = jnp.asarray(phases, jnp.int32)
        carry_end, frames, recs, active = smapped(scene, poses, counts,
                                                  phases, carries)
        return StreamsResult(frames=frames, records=StackedRecords(recs),
                             phases=phases, counts=counts,
                             frame_active=active, carries=carry_end)
    return fn
