"""DeepSeek-67B — llama-architecture dense decoder. [arXiv:2401.02954; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-67b", family="dense",
    num_layers=95, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=22016, vocab_size=102400,
    notes="95L x 8192d: FSDP(+TP) mandatory to fit 16GB/chip; see "
          "EXPERIMENTS.md §Perf hillclimb.",
)
