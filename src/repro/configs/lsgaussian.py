"""LS-Gaussian renderer "architecture" — the paper's own workload as an
extra dry-run config: gaussian-parallel preprocess + tile-parallel raster.
Not part of the assigned 10; exercised by launch/dryrun.py --arch lsgaussian.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class RendererArch:
    name: str = "lsgaussian"
    family: str = "renderer"
    num_gaussians: int = 2_000_000
    image_width: int = 1920
    image_height: int = 1088
    tile_capacity: int = 1024
    sh_degree: int = 3


CONFIG = RendererArch()
