"""MiniCPM3-4B — dense decoder with Multi-head Latent Attention (MLA).

[hf:openbmb/MiniCPM3-4B; hf] 62L d_model=2560 40H d_ff=6400 vocab=73448.
MLA dims follow the HF config: q_lora_rank=768, kv_lora_rank=256,
qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm3-4b", family="dense",
    num_layers=62, d_model=2560, num_heads=40, num_kv_heads=40,
    d_ff=6400, vocab_size=73448,
    attention="mla", q_lora_rank=768, kv_lora_rank=256,
    rope_head_dim=32, nope_head_dim=64, v_head_dim=64,
    notes="MLA latent cache: decode stores (kv_lora+rope)=288/token vs "
          "GQA 40*64*2=5120 — 17.8x smaller KV cache.",
)
