"""Architecture config schema for the assigned model zoo.

Every assigned architecture gets a module in this package exporting
``CONFIG`` (the exact published dims) — the registry in ``__init__``
resolves ``--arch <id>``. ``reduced()`` derives the CPU smoke-test config
(same family and code path, tiny dims).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // num_heads

    # --- attention ------------------------------------------------------
    attention: str = "gqa"      # gqa | mla | none
    rope_theta: float = 10000.0

    # --- MLA (MiniCPM3 / DeepSeek-V2 style) ------------------------------
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    rope_head_dim: int = 0
    nope_head_dim: int = 0
    v_head_dim: int = 0

    # --- MoE --------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_capacity_factor: float = 1.25
    # decode-regime capacity factor; 0 = dropless (capacity = tokens).
    # See EXPERIMENTS.md §Perf: dropless decode computes every expert over
    # a mostly-empty buffer — factor ~4 cuts decode MoE FLOPs ~t*k/(4e)x.
    moe_decode_capacity_factor: float = 0.0

    # --- SSM (Mamba-2 / SSD) ----------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv_width: int = 4

    # --- hybrid (Zamba2): shared attn+MLP block every k SSM layers --------
    shared_attn_every: int = 0
    shared_attn_d_ff: int = 0

    # --- encoder-decoder (Whisper) ----------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 0        # precomputed frame embeddings (stub frontend)

    # --- VLM (InternVL2): vision-prefix embeddings (stub frontend) --------
    num_vision_tokens: int = 0

    # --- MLP / misc --------------------------------------------------------
    mlp_type: str = "swiglu"    # swiglu | gelu
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- execution ---------------------------------------------------------
    scan_layers: bool = True
    remat: str = "full"         # none | full | dots
    dtype: str = "bfloat16"
    notes: str = ""

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    def param_count(self) -> int:
        """Analytic parameter count (embedding + layers), for 6ND."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        n = v * d * (1 if self.tie_embeddings else 2)
        if self.attention == "mla":
            attn = (d * self.q_lora_rank
                    + self.q_lora_rank * self.num_heads
                    * (self.nope_head_dim + self.rope_head_dim)
                    + d * self.kv_lora_rank + d * self.rope_head_dim
                    + self.kv_lora_rank * self.num_heads
                    * (self.nope_head_dim + self.v_head_dim)
                    + self.num_heads * self.v_head_dim * d)
        elif self.attention == "gqa":
            attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) \
                + self.num_heads * hd * d
        else:
            attn = 0
        mlp_mult = 3 if self.mlp_type == "swiglu" else 2
        dense_mlp = mlp_mult * d * ff
        if self.family == "moe":
            experts = self.num_experts + self.num_shared_experts
            mlp = experts * mlp_mult * d * ff + d * self.num_experts
        else:
            mlp = dense_mlp
        if self.family in ("ssm", "hybrid"):
            d_in = self.d_inner
            ssm = (d * (2 * d_in + 2 * self.ssm_state + self.ssm_heads)
                   + d_in * d + (d_in + 2 * self.ssm_state)
                   * self.ssm_conv_width + 3 * self.ssm_heads)
            if self.family == "hybrid":
                shared = d * hd * (self.num_heads + 2 * self.num_kv_heads) \
                    + self.num_heads * hd * d \
                    + 3 * d * (self.shared_attn_d_ff or ff)
                n += shared  # invoked repeatedly, stored once
                n += self.num_layers * ssm
                return n
            n += self.num_layers * ssm
            return n
        n += self.num_layers * (attn + mlp)
        if self.encoder_layers:
            n += self.encoder_layers * (attn + dense_mlp)
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k + shared)."""
        if self.family != "moe":
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        mlp_mult = 3 if self.mlp_type == "swiglu" else 2
        total = self.param_count()
        all_experts = (self.num_experts + self.num_shared_experts) \
            * mlp_mult * d * ff * self.num_layers
        active = (self.experts_per_token + self.num_shared_experts) \
            * mlp_mult * d * ff * self.num_layers
        return total - all_experts + active

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=min(self.num_layers, 2 if not self.shared_attn_every
                           else self.shared_attn_every + 1),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(max(self.num_kv_heads // 8, 1), 4)
            if self.num_kv_heads else 0,
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            q_lora_rank=64 if self.q_lora_rank else 0,
            kv_lora_rank=32 if self.kv_lora_rank else 0,
            rope_head_dim=16 if self.rope_head_dim else 0,
            nope_head_dim=16 if self.nope_head_dim else 0,
            v_head_dim=32 if self.v_head_dim else 0,
            num_experts=min(self.num_experts, 8),
            experts_per_token=min(self.experts_per_token, 2),
            num_shared_experts=min(self.num_shared_experts, 1),
            moe_decode_capacity_factor=0.0,  # smoke tests: exact/dropless
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=32 if self.ssm_state else 64,
            ssm_chunk=32,
            shared_attn_every=min(self.shared_attn_every, 2)
            if self.shared_attn_every else 0,
            shared_attn_d_ff=256 if self.shared_attn_d_ff else 0,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 32),
            num_vision_tokens=min(self.num_vision_tokens, 16),
            scan_layers=False,
            remat="none",
            dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One dry-run cell: kind selects which step gets lowered."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", 4096, 256, "train"),
    ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    ShapeSpec("decode_32k", 32768, 128, "decode"),
    ShapeSpec("long_500k", 524288, 1, "decode"),
)

# long_500k is sub-quadratic-only (assignment): SSM + hybrid run it, pure
# full-attention archs skip it (recorded in DESIGN.md §4 + EXPERIMENTS.md).
LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    if shape.name == "long_500k" and cfg.family not in LONG_CONTEXT_FAMILIES:
        return False, "long_500k requires sub-quadratic attention " \
                      f"({cfg.family} is full-attention)"
    return True, ""
