"""Zamba2-7B — Mamba2 backbone with a SHARED attention+MLP block invoked
every 6 SSM layers. [arXiv:2411.15242; unverified]
81 mamba2 layers (d=3584, state=64); shared block: 32H GQA + 14336 MLP."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    d_ff=14336, vocab_size=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    shared_attn_every=6, shared_attn_d_ff=14336,
    notes="runs long_500k (sub-quadratic backbone; 13 shared-attn "
          "invocations hold the only KV cache).",
)
