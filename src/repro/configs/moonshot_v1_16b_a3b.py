"""Moonlight-16B-A3B (kimi/moonshot) — fine-grained MoE, 64 experts top-6
+ 2 shared experts (DeepSeek-V3-style). [hf:moonshotai/Moonlight-16B-A3B; hf]
d_ff=1408 is the per-expert intermediate size."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=163840,
    moe_decode_capacity_factor=4.0,  # capped decode buffer (EXPERIMENTS.md §Perf cell B)
    num_experts=64, experts_per_token=6, num_shared_experts=2,
    notes="MoE dispatch uses the LDU-style capacity cap (DESIGN.md §4).",
)
