"""Mamba2-780m — attention-free SSD. [arXiv:2405.21060; unverified]
48L d_model=1536, ssm_state=128, expand=2 -> d_inner=3072, head_dim 64."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-780m", family="ssm",
    num_layers=48, d_model=1536, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280, attention="none",
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, tie_embeddings=True,
    notes="runs long_500k (recurrent state, O(1) per decode step).",
)
