"""Whisper-large-v3 backbone — encoder-decoder transformer.
[arXiv:2212.04356; unverified]

The conv/mel frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings (B, 1500, d_model). Positional scheme
simplified to sinusoidal-equivalent RoPE on the decoder; encoder is
position-free over stub embeddings (recorded in DESIGN.md §4).
long_500k skipped (enc-dec, 30 s windows)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3", family="encdec",
    num_layers=32, d_model=1280, num_heads=20, num_kv_heads=20,
    d_ff=5120, vocab_size=51866, mlp_type="gelu",
    encoder_layers=32, encoder_seq=1500,
)
