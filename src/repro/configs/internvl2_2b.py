"""InternVL2-2B backbone — InternLM2-1.8B LM + ViT patch-embedding prefix.
[arXiv:2404.16821; hf]

The InternViT frontend is a STUB per the assignment: input_specs()
provides precomputed patch embeddings (B, 256, d_model) consumed as a
sequence prefix through a learned projection."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b", family="vlm",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8,
    d_ff=8192, vocab_size=92553,
    num_vision_tokens=256,
)
