"""Architecture registry: ``get_config("<arch-id>")`` for ``--arch`` flags."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import (ArchConfig, ShapeSpec, SHAPES,
                                shape_applicable)

# Pruned to the configs the tests, examples, and launch tools actually
# exercise (dense MLA / dense GQA / dense MQA / fine-grained MoE — one
# per code-path family still in use); the remaining seed archs
# (encdec/ssm/hybrid/vlm shells) were dead weight riding every
# collection pass.
ARCH_IDS = (
    "minicpm3-4b",
    "yi-9b",
    "starcoder2-7b",
    "moonshot-v1-16b-a3b",
)

EXTRA_IDS = ("lsgaussian",)


def _module_name(arch_id: str) -> str:
    return "repro.configs." + arch_id.replace("-", "_")


def get_config(arch_id: str):
    if arch_id.endswith("-smoke"):
        return get_config(arch_id[: -len("-smoke")]).reduced()
    if arch_id not in ARCH_IDS + EXTRA_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: "
                       f"{ARCH_IDS + EXTRA_IDS}")
    return importlib.import_module(_module_name(arch_id)).CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def get_shape(name: str) -> ShapeSpec:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)
