"""Architecture registry: ``get_config("<arch-id>")`` for ``--arch`` flags."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import (ArchConfig, ShapeSpec, SHAPES,
                                shape_applicable)

ARCH_IDS = (
    "minicpm3-4b",
    "yi-9b",
    "deepseek-67b",
    "starcoder2-7b",
    "moonshot-v1-16b-a3b",
    "llama4-maverick-400b-a17b",
    "whisper-large-v3",
    "zamba2-7b",
    "mamba2-780m",
    "internvl2-2b",
)

EXTRA_IDS = ("lsgaussian",)


def _module_name(arch_id: str) -> str:
    return "repro.configs." + arch_id.replace("-", "_")


def get_config(arch_id: str):
    if arch_id.endswith("-smoke"):
        return get_config(arch_id[: -len("-smoke")]).reduced()
    if arch_id not in ARCH_IDS + EXTRA_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: "
                       f"{ARCH_IDS + EXTRA_IDS}")
    return importlib.import_module(_module_name(arch_id)).CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def get_shape(name: str) -> ShapeSpec:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)
