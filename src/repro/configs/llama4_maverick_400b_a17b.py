"""Llama-4-Maverick-400B-A17B — MoE 128 experts top-1 + shared expert.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified] Dims per assignment;
every layer routed (early-fusion multimodal frontend out of scope)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=8192, vocab_size=202048,
    moe_decode_capacity_factor=4.0,  # capped decode buffer (EXPERIMENTS.md §Perf cell B)
    num_experts=128, experts_per_token=1, num_shared_experts=1,
    rope_theta=500000.0,
)
