"""Pipeline parallelism over the "pod" axis (beyond-paper, DESIGN.md §5).

The multi-pod mesh's "pod" axis can act as DP (default) or as GPipe-style
pipeline stages — cross-pod ICI is the slowest fabric, and pipelining
sends only (micro_batch, seq, d_model) activations across it once per
microbatch instead of all-reducing every gradient.

Mechanics (shard_map over "pod"):
  - the layer-stacked params (L, ...) are sharded P("pod", ...): stage s
    holds layers [s*L/P, (s+1)*L/P);
  - microbatches stream through a circular ``collective_permute``; stage s
    idles for s warmup ticks (GPipe bubble = (P-1)/(M+P-1));
  - the returned activations are the LAST stage's outputs, re-distributed.

Forward-only here (decode/prefill pipelining + inference serving); the
train path composes with jax.grad through ppermute. Correctness is tested
on an 8-device host mesh in tests/test_pipeline.py.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from jax.experimental.shard_map import shard_map


def pipeline_apply(layer_fn: Callable, params_stacked, x, *,
                   mesh: Mesh, num_micro: int, axis: str = "pod"):
    """Run ``layer_fn`` stacks as a pipeline over ``axis``.

    layer_fn(params_slice, x) -> x, applied to the local layer shard via
    an inner scan. x: (B, S, D) with B divisible by num_micro.
    params_stacked: pytree with leading layer dim divisible by the axis
    size.
    """
    n_stages = mesh.shape[axis]
    b = x.shape[0]
    assert b % num_micro == 0, (b, num_micro)
    micro = b // num_micro

    def local_layers(local_params, h):
        def body(carry, lp):
            return layer_fn(lp, carry), None
        out, _ = jax.lax.scan(body, h, local_params)
        return out

    def staged(local_params, x_local):
        stage = jax.lax.axis_index(axis)
        # all microbatches start on stage 0: gather x there.
        x_all = jax.lax.all_gather(x_local, axis, tiled=True)  # (B,S,D)
        mbs = x_all.reshape(num_micro, micro, *x_all.shape[1:])
        n_ticks = num_micro + n_stages - 1
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def tick(carry, t):
            buf, outputs = carry
            # stage 0 injects microbatch t (if any); others use received
            inject = mbs[jnp.minimum(t, num_micro - 1)]
            h_in = jnp.where((stage == 0), inject, buf)
            h_out = local_layers(local_params, h_in)
            # live iff this stage is processing a real microbatch
            live = (t >= stage) & (t - stage < num_micro)
            h_out = jnp.where(live, h_out, buf)
            # last stage writes its finished microbatch to the output slot
            done_idx = t - (n_stages - 1)
            is_done = (stage == n_stages - 1) & (done_idx >= 0) \
                & (done_idx < num_micro)
            outputs = jax.lax.cond(
                is_done,
                lambda o: o.at[jnp.maximum(done_idx, 0)].set(h_out),
                lambda o: o, outputs)
            nxt = jax.lax.ppermute(h_out, axis, perm)
            return (nxt, outputs), None

        buf0 = jnp.zeros_like(mbs[0])
        outs0 = jnp.zeros_like(mbs)
        (_, outputs), _ = jax.lax.scan(tick, (buf0, outs0),
                                       jnp.arange(n_ticks))
        # outputs are only valid on the last stage; gather and select it so
        # the out_spec can be replicated-over-pod.
        gathered = jax.lax.all_gather(outputs, axis)   # (P, M, micro, ...)
        out = gathered[n_stages - 1].reshape(b, *x_all.shape[1:])
        return out

    param_specs = jax.tree_util.tree_map(
        lambda l: P(axis, *([None] * (l.ndim - 1))), params_stacked)
    fn = shard_map(staged, mesh=mesh,
                   in_specs=(param_specs, P(axis)),
                   out_specs=P(),
                   check_rep=False)
    return fn(params_stacked, x)


def bubble_fraction(num_stages: int, num_micro: int) -> float:
    """GPipe bubble overhead — the schedule-efficiency napkin number."""
    return (num_stages - 1) / (num_micro + num_stages - 1)
