"""Fault-tolerance utilities (DESIGN.md §5).

The concrete mechanisms live where they act:
  - atomic reshardable checkpoints ......... train/checkpoint.py
  - auto-resume + step watchdog ............ launch/train.py
  - elastic re-mesh on restore ............. checkpoint.restore(shardings=)
  - deterministic seekable data ............ train/data.py

This module adds the *decision* layer a 1000-node deployment needs:
classify a failure, pick an action, and (in tests) inject failures.
"""
from __future__ import annotations

import dataclasses
import enum
import time
from typing import Callable, Optional


class FailureKind(enum.Enum):
    STEP_TIMEOUT = "step_timeout"        # straggler / hung collective
    DEVICE_LOST = "device_lost"          # pod or chip dropped
    NAN_LOSS = "nan_loss"                # numeric blowup
    CHECKPOINT_IO = "checkpoint_io"      # storage hiccup


@dataclasses.dataclass
class Policy:
    max_retries_per_step: int = 2
    nan_rollback_steps: int = 1          # restore N checkpoints back
    straggler_grace: float = 2.0         # x median step time
    remesh_on_device_loss: bool = True   # shrink mesh instead of waiting


def classify(exc: BaseException, *, step_s: Optional[float] = None,
             median_s: Optional[float] = None,
             policy: Policy = Policy()) -> FailureKind:
    name = type(exc).__name__.lower()
    msg = str(exc).lower()
    if "nan" in msg:
        return FailureKind.NAN_LOSS
    if any(k in msg for k in ("device", "slice", "halted", "ici")):
        return FailureKind.DEVICE_LOST
    if any(k in name for k in ("oserror", "ioerror")) or "no space" in msg:
        return FailureKind.CHECKPOINT_IO
    return FailureKind.STEP_TIMEOUT


def action_for(kind: FailureKind, policy: Policy = Policy()) -> str:
    """Decision table — what the 1000-node driver does per failure kind."""
    return {
        FailureKind.STEP_TIMEOUT: "retry step; after "
        f"{policy.max_retries_per_step} retries, exclude the slow host "
        "and re-mesh (checkpoint.restore with the smaller mesh's "
        "shardings)",
        FailureKind.DEVICE_LOST: "restore latest checkpoint onto the "
        "surviving mesh (elastic re-mesh) and continue; data cursor "
        "resumes from the checkpointed step",
        FailureKind.NAN_LOSS: f"roll back {policy.nan_rollback_steps} "
        "checkpoint(s), halve LR for the replayed window, continue",
        FailureKind.CHECKPOINT_IO: "keep training; retry the save with "
        "exponential backoff (atomic tmp+rename means no torn state)",
    }[kind]


class StepWatchdog:
    """Tracks step durations; flags stragglers at grace x running median."""

    def __init__(self, policy: Policy = Policy()):
        self.policy = policy
        self.durations: list = []
        self.flagged = 0

    def observe(self, seconds: float) -> bool:
        self.durations.append(seconds)
        n = len(self.durations)
        if n < 5:
            return False
        med = sorted(self.durations)[n // 2]
        if seconds > self.policy.straggler_grace * med:
            self.flagged += 1
            return True
        return False
