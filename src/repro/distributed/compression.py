"""Gradient compression for the data-parallel all-reduce (beyond-paper).

int8 quantized all-reduce with per-tensor scales and error feedback
(residual carried across steps), via shard_map over the data axes. At 512
chips the DP gradient all-reduce is the dominant cross-pod collective;
int8 cuts its bytes 2x vs bf16 / 4x vs f32 (see EXPERIMENTS.md §Perf).

``compressed_psum_grads`` is a drop-in around the grad pytree inside a
shard_map'd step; error feedback keeps the quantization bias bounded
(property test: tests/test_distributed.py).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis_name, residual: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """int8 psum with error feedback. Returns (mean grad, new residual).

    Caller must be inside shard_map/pmap over ``axis_name``.
    """
    x = x.astype(jnp.float32) + residual
    q, scale = quantize_int8(x)
    local_deq = dequantize_int8(q, scale)
    new_residual = x - local_deq
    # int8 tensors sum as int32 to avoid overflow at 512 participants;
    # per-shard scales are tiny and ride a fp32 psum.
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    # scales differ per shard: sum of (q_i * s_i) != s * sum(q_i); use the
    # mean-scale approximation + correction via psum of scales
    n = jax.lax.psum(jnp.ones(()), axis_name)
    # exact: psum of dequantized values, but that defeats compression; the
    # wire format is (int32 accumulated q, fp32 scale). We approximate the
    # per-shard scale with its psum mean — error absorbed by feedback.
    scale_mean = jax.lax.psum(scale, axis_name) / n
    summed = total.astype(jnp.float32) * scale_mean
    return summed / n, new_residual


def compressed_psum_grads(grads, axis_name, residuals):
    """Apply compressed_psum leaf-wise over a grad pytree."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    out_g, out_r = [], []
    for g, r in zip(flat_g, flat_r):
        mg, nr = compressed_psum(g, axis_name, r)
        out_g.append(mg.astype(g.dtype))
        out_r.append(nr)
    return treedef.unflatten(out_g), treedef.unflatten(out_r)


def zero_residuals(grads):
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)
