"""Sharding rules: param/optimizer/cache/input PartitionSpecs.

Strategy (DESIGN.md §5): 2-D sharded weights — contraction/feature dim over
"model" (TP), the other large dim over "data" (FSDP/ZeRO-3); experts over
"model" (EP); batch over ("pod","data"); KV caches shard batch over "data"
and heads over "model" when divisible, falling back to sequence sharding
for batch-1 long-context decode (flash-decoding style).

Rules are name-based over the param tree (the last dict key identifies the
leaf; stacked layer dims are detected by rank and get a leading None).
Every axis is divisibility-checked against the mesh — a non-divisible dim
degrades to replication rather than failing to lower.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec

# name -> spec template over the UNSTACKED rank. "F" = fsdp axis ("data"),
# "M" = tensor axis ("model"), None = replicate.
_PARAM_RULES: Dict[str, Tuple[Optional[str], ...]] = {
    # embeddings / head
    "embed": ("M", "F"),
    "lm_head": ("F", "M"),
    "vision_proj": ("F", "M"),
    # attention (GQA + shared/cross variants share names)
    "wq": ("F", "M", None),
    "wk": ("F", "M", None),
    "wv": ("F", "M", None),
    "wo": ("M", None, "F"),
    # MLA
    "w_dq": ("F", None),
    "w_uq": (None, "M", None),
    "w_dkv": ("F", None),
    "w_kr": ("F", None),
    "w_uk": (None, "M", None),
    "w_uv": (None, "M", None),
    # dense MLP (rank 2) / MoE experts (rank 3, leading E) disambiguated
    # by rank in _spec_for.
    "w_in": ("F", "M"),
    "w_gate": ("F", "M"),
    "w_out": ("M", "F"),
    "router": ("F", None),
    # mamba
    "conv_w": ("M", None),
    "conv_b": ("M",),
    "a_log": (None,),
    "d_skip": (None,),
    "dt_bias": (None,),
    "out_norm": (None,),
    # norms / scalars
    "ln1": (None,), "ln2": (None,), "ln_x": (None,),
    "final_norm": (None,), "enc_final_norm": (None,),
    "q_norm": (None,), "kv_norm": (None,),
    "step": (),
}

_MOE_RULES: Dict[str, Tuple[Optional[str], ...]] = {
    # experts over "model" (EP), d/f over "data" (FSDP)
    "w_in": ("M", "F", None),
    "w_gate": ("M", "F", None),
    "w_out": ("M", "F", None),
}


def fsdp_axis(mesh: Mesh) -> Any:
    return "data"


def batch_axes(mesh: Mesh) -> Any:
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def _axis_ok(mesh: Mesh, axis: Optional[str], dim: int) -> Optional[str]:
    if axis is None:
        return None
    name = {"F": "data", "M": "model"}[axis]
    size = mesh.shape[name]
    return name if dim % size == 0 else None


def _path_keys(path):
    for p in path:
        key = getattr(p, "key", None)
        if key is None:
            key = getattr(p, "name", None)   # NamedTuple fields
        if key is None:
            key = getattr(p, "idx", None)    # sequences
        yield key


def _spec_for(path, leaf, mesh: Mesh) -> P:
    name = None
    in_moe = False
    for key in _path_keys(path):
        if key in ("moe",):
            in_moe = True
        if key == "shared":
            in_moe = False  # shared expert is a plain MLP
        if key is not None and not isinstance(key, int):
            name = key
    if name not in _PARAM_RULES and name not in _MOE_RULES:
        raise KeyError(f"no sharding rule for param {name!r} "
                       f"(path {jax.tree_util.keystr(path)})")
    rule = _PARAM_RULES.get(name, ())
    if in_moe and name in _MOE_RULES and leaf.ndim >= 3:
        rule = _MOE_RULES[name]
    ndim = np.ndim(leaf) if not hasattr(leaf, "ndim") else leaf.ndim
    if ndim == len(rule) + 1:        # stacked layer/group leading dim
        rule = (None,) + rule
    elif ndim == len(rule) + 2:      # zamba grouped stacking (G, k, ...)
        rule = (None, None) + rule
    elif ndim != len(rule):
        raise ValueError(f"rank mismatch for {name}: rule {rule}, "
                         f"shape {leaf.shape}")
    axes = tuple(_axis_ok(mesh, a, leaf.shape[i])
                 for i, a in enumerate(rule))
    return P(*axes)


def param_shardings(tree, mesh: Mesh):
    """NamedSharding pytree congruent with any params/opt-state tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, _spec_for(path, leaf, mesh)),
        tree)


# ---------------------------------------------------------------------------
# inputs / caches
# ---------------------------------------------------------------------------

def batch_shardings(batch_tree, mesh: Mesh):
    """tokens/labels (B, S) and stub embeddings (B, T, D): batch over the
    data axes when divisible, replicate otherwise (batch-1 decode)."""
    baxes = batch_axes(mesh)
    dsize = np.prod([mesh.shape[a] for a in
                     (baxes if isinstance(baxes, tuple) else (baxes,))])

    def spec(leaf):
        b = leaf.shape[0]
        lead = baxes if b % dsize == 0 else None
        return NamedSharding(mesh, P(lead, *([None] * (leaf.ndim - 1))))

    return jax.tree_util.tree_map(spec, batch_tree)


def cache_shardings(cache_tree, mesh: Mesh):
    """KV caches: (L, B, G, S, K) — batch over "data" when divisible, else
    the SEQUENCE axis is sharded over "data" (flash-decoding layout for
    long_500k). Heads over "model" when divisible. SSM states: heads over
    "model". MLA latent caches: batch over "data", latent replicated."""
    def spec(leaf):
        shape = leaf.shape
        nd = leaf.ndim
        data = mesh.shape["data"]
        model = mesh.shape["model"]
        if nd == 5:    # (L, B, G, S, K) kv cache
            if shape[1] % data == 0:
                return NamedSharding(mesh, P(
                    None, "data",
                    "model" if shape[2] % model == 0 else None, None, None))
            return NamedSharding(mesh, P(
                None, None, "model" if shape[2] % model == 0 else None,
                "data" if shape[3] % data == 0 else None, None))
        if nd == 4:    # (L, B, S, C) MLA latent / (L, B, conv_dim, W)
            if shape[1] % data == 0:
                return NamedSharding(mesh, P(None, "data", None, None))
            # batch-1 long context: shard MLA seq axis over data
            return NamedSharding(mesh, P(
                None, None, "data" if shape[2] % data == 0 else None, None))
        if nd == 3:    # (B, enc_seq, D) encoder output
            return NamedSharding(mesh, P(
                "data" if shape[0] % data == 0 else None, None, None))
        if nd == 0:
            return NamedSharding(mesh, P())
        # ssm state (L, B, H, P, N) handled by nd==5 above; fallback:
        return NamedSharding(mesh, P(*([None] * nd)))

    return jax.tree_util.tree_map(spec, cache_tree)


def replicated(tree, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda leaf: NamedSharding(
            mesh, P(*([None] * getattr(leaf, "ndim", 0)))), tree)
