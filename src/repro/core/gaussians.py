"""3D Gaussian scene representation.

A scene is a flat pytree of per-Gaussian parameters (kerbl et al. 3DGS):
position, anisotropic scale (log-space), rotation quaternion, opacity
(logit-space) and spherical-harmonic color coefficients.

Everything here is shape-static pure JAX so scenes can be sharded
(gaussian axis) and jitted end to end.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

# Real SH basis constants (degree <= 3), matching the reference 3DGS CUDA
# implementation.
SH_C0 = 0.28209479177387814
SH_C1 = 0.4886025119029199
SH_C2 = (1.0925484305920792, -1.0925484305920792, 0.31539156525252005,
         -1.0925484305920792, 0.5462742152960396)
SH_C3 = (-0.5900435899266435, 2.890611442640554, -0.4570457994644658,
         0.3731763325901154, -0.4570457994644658, 1.445305721320277,
         -0.5900435899266435)


class GaussianScene(NamedTuple):
    """Per-Gaussian parameters. N = number of Gaussians, K = (sh_degree+1)^2."""

    means: jax.Array          # (N, 3) world-space centers
    log_scales: jax.Array     # (N, 3) log of per-axis stddev
    quats: jax.Array          # (N, 4) rotation quaternion (w, x, y, z), unnormalized
    opacity_logits: jax.Array  # (N,)  sigmoid -> opacity in (0, 1)
    sh: jax.Array             # (N, K, 3) SH color coefficients

    @property
    def num_gaussians(self) -> int:
        return self.means.shape[0]

    @property
    def sh_degree(self) -> int:
        k = self.sh.shape[1]
        return {1: 0, 4: 1, 9: 2, 16: 3}[k]


def opacities(scene: GaussianScene) -> jax.Array:
    """(N,) opacity in (0,1)."""
    return jax.nn.sigmoid(scene.opacity_logits)


def quat_to_rotmat(quats: jax.Array) -> jax.Array:
    """(..., 4) wxyz quaternion -> (..., 3, 3) rotation matrix."""
    q = quats / (jnp.linalg.norm(quats, axis=-1, keepdims=True) + 1e-12)
    w, x, y, z = q[..., 0], q[..., 1], q[..., 2], q[..., 3]
    r00 = 1 - 2 * (y * y + z * z)
    r01 = 2 * (x * y - w * z)
    r02 = 2 * (x * z + w * y)
    r10 = 2 * (x * y + w * z)
    r11 = 1 - 2 * (x * x + z * z)
    r12 = 2 * (y * z - w * x)
    r20 = 2 * (x * z - w * y)
    r21 = 2 * (y * z + w * x)
    r22 = 1 - 2 * (x * x + y * y)
    rows = [jnp.stack([r00, r01, r02], -1),
            jnp.stack([r10, r11, r12], -1),
            jnp.stack([r20, r21, r22], -1)]
    return jnp.stack(rows, -2)


def covariances(scene: GaussianScene) -> jax.Array:
    """World-space 3x3 covariance per Gaussian: R S S^T R^T. (N, 3, 3)."""
    rot = quat_to_rotmat(scene.quats)                     # (N, 3, 3)
    scale = jnp.exp(scene.log_scales)                      # (N, 3)
    m = rot * scale[:, None, :]                            # R @ diag(s)
    return m @ jnp.swapaxes(m, -1, -2)


def eval_sh(sh: jax.Array, dirs: jax.Array) -> jax.Array:
    """Evaluate SH color in view directions.

    sh: (N, K, 3) with K in {1, 4, 9, 16}; dirs: (N, 3) unit vectors
    (gaussian center - camera position, normalized). Returns (N, 3) RGB,
    clamped at 0 like the reference implementation (+0.5 offset).
    """
    k = sh.shape[1]
    result = SH_C0 * sh[:, 0]
    if k > 1:
        x, y, z = dirs[:, 0:1], dirs[:, 1:2], dirs[:, 2:3]
        result = (result - SH_C1 * y * sh[:, 1] + SH_C1 * z * sh[:, 2]
                  - SH_C1 * x * sh[:, 3])
        if k > 4:
            xx, yy, zz = x * x, y * y, z * z
            xy, yz, xz = x * y, y * z, x * z
            result = (result
                      + SH_C2[0] * xy * sh[:, 4]
                      + SH_C2[1] * yz * sh[:, 5]
                      + SH_C2[2] * (2.0 * zz - xx - yy) * sh[:, 6]
                      + SH_C2[3] * xz * sh[:, 7]
                      + SH_C2[4] * (xx - yy) * sh[:, 8])
            if k > 9:
                result = (result
                          + SH_C3[0] * y * (3 * xx - yy) * sh[:, 9]
                          + SH_C3[1] * xy * z * sh[:, 10]
                          + SH_C3[2] * y * (4 * zz - xx - yy) * sh[:, 11]
                          + SH_C3[3] * z * (2 * zz - 3 * xx - 3 * yy) * sh[:, 12]
                          + SH_C3[4] * x * (4 * zz - xx - yy) * sh[:, 13]
                          + SH_C3[5] * z * (xx - yy) * sh[:, 14]
                          + SH_C3[6] * x * (xx - 3 * yy) * sh[:, 15])
    return jnp.maximum(result + 0.5, 0.0)


def rgb_to_sh_dc(rgb: jax.Array) -> jax.Array:
    """Inverse of the degree-0 term: store a flat RGB as the DC coefficient."""
    return (rgb - 0.5) / SH_C0
