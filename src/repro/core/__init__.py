"""LS-Gaussian core: the paper's contribution (TWSR / DPES / TAIT / LDU)."""
