"""TilePlan — the compacted per-frame render plan (DESIGN.md §2).

The paper's central claim is that streaming 3DGS should do work
proportional to what actually changed: TWSR picks the re-render tile set
and the LDU maps predicted per-tile workloads onto parallel blocks. The
``TilePlan`` is that decision reified as a first-class device value:

  tile_ids       (R,) int32  tile ids in Morton visit order, active
                             slots first — R is a *static* slot count, so
                             every downstream stage (intersect, binning,
                             sort, raster) compiles to shapes that scale
                             with R instead of the full tile count T.
  slot_active    (R,) bool   padded slots (beyond the re-render set) are
                             inactive and contribute nothing.
  workload       (R,) int32  DPES-predicted pairs per slot (the LDU's
                             scheduling input; filled after binning).
  block_of       (R,) int32  device-LDU block assignment (-1 inactive).
  order_in_block (R,) int32  light-to-heavy execution position.
  overflow_tiles ()   int32  re-render tiles dropped because they did not
                             fit in R (they degrade to interpolation).

Full frames carry an all-tiles plan (R = T); TWSR sparse frames carry the
warp-predicted re-render set compacted to ``R = rerender_capacity``. Both
render through the same ``pipeline.render_planned_frame``. Everything is
shape-static and jnp, so plans are built AND scheduled inside the jitted
``lax.scan`` streaming engine (core/engine.py) with no host callback;
numpy ``load_balance.schedule`` remains the golden reference.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import load_balance


class TilePlan(NamedTuple):
    """Compacted frame plan; see module docstring for the field contract."""

    tile_ids: jax.Array        # (R,) int32
    slot_active: jax.Array     # (R,) bool
    workload: jax.Array        # (R,) int32
    block_of: jax.Array        # (R,) int32
    order_in_block: jax.Array  # (R,) int32
    overflow_tiles: jax.Array  # () int32

    @property
    def num_slots(self) -> int:
        return self.tile_ids.shape[0]


def _blank(tile_ids: jax.Array, slot_active: jax.Array,
           overflow_tiles: jax.Array) -> TilePlan:
    r = tile_ids.shape[0]
    return TilePlan(
        tile_ids=tile_ids.astype(jnp.int32), slot_active=slot_active,
        workload=jnp.zeros((r,), jnp.int32),
        block_of=jnp.full((r,), -1, jnp.int32),
        order_in_block=jnp.zeros((r,), jnp.int32),
        overflow_tiles=overflow_tiles)


def full_plan(tiles_x: int, tiles_y: int) -> TilePlan:
    """All-tiles plan (R = T) in Morton visit order — key frames."""
    visit = jnp.argsort(load_balance.morton_rank(tiles_x, tiles_y))
    t = tiles_x * tiles_y
    return _blank(visit, jnp.ones((t,), bool), jnp.int32(0))


def sparse_plan(rerender: jax.Array, tiles_x: int, tiles_y: int,
                capacity: Optional[int]) -> TilePlan:
    """Compact the TWSR re-render set into R = ``capacity`` plan slots.

    Re-render tiles are taken in Morton order; with more re-render tiles
    than slots, the Morton tail overflows (counted, degrades to
    interpolation). ``capacity=None`` keeps R = T (no compaction — the
    dense reference path).
    """
    t = rerender.shape[0]
    r = t if capacity is None else min(int(capacity), t)
    rank = load_balance.morton_rank(tiles_x, tiles_y)
    # Active tiles first (in Morton order), inactive Morton-ordered after.
    ids = jnp.argsort(jnp.where(rerender, rank, t + rank))[:r]
    slot_active = rerender[ids]
    overflow = (jnp.sum(rerender.astype(jnp.int32))
                - jnp.sum(slot_active.astype(jnp.int32)))
    return _blank(ids, slot_active, overflow)


def schedule_plan(plan: TilePlan, workload: jax.Array,
                  num_blocks: int) -> TilePlan:
    """Run the device LDU over the plan's slots (paper Sec. V-B).

    Slots are already in Morton visit order, so the greedy capacity fill
    scans them directly; intra-block order is light-to-heavy with tile-id
    tie-breaks — bit-identical to numpy ``load_balance.schedule`` with
    ``policy="ls_gaussian"`` on the same workloads/active set.
    """
    workload = workload.astype(jnp.int32)
    block_of = load_balance.greedy_fill(workload, plan.slot_active,
                                        num_blocks)
    order = load_balance.order_within_blocks(block_of, workload,
                                             plan.tile_ids)
    return plan._replace(workload=workload, block_of=block_of,
                         order_in_block=order)


def scatter_slots(plan: TilePlan, values: jax.Array, num_tiles: int,
                  fill=0) -> jax.Array:
    """(R, ...) per-slot values -> (T, ...) per-tile, ``fill`` elsewhere.

    Inactive slots are masked to ``fill`` so padded slots never leak
    stale values into the per-tile view.
    """
    shape = (num_tiles,) + values.shape[1:]
    masked = jnp.where(
        plan.slot_active.reshape((-1,) + (1,) * (values.ndim - 1)),
        values, jnp.asarray(fill, values.dtype))
    return jnp.full(shape, fill, values.dtype).at[plan.tile_ids].set(masked)


def rerender_demand(active, overflow_tiles):
    """Per-frame re-render *demand*: tiles that wanted re-rendering.

    The exact inverse of ``sparse_plan``'s compaction: ``active`` (the
    ``FrameRecord.active`` flags, last axis T) counts the tiles that won a
    plan slot and ``overflow_tiles`` the Morton tail that was dropped to
    interpolation — their sum is the slot count an uncapped plan would
    have used. Works on stacked ``(F, ..., T)`` record arrays (jnp or
    numpy); the serving layer's ``serve.cache.suggest_capacity`` feeds
    quantiles of this into the bucketed-R executable choice.

    Dtype contract: the result is always int32 regardless of the inputs'
    dtypes (``overflow_tiles`` records arrive as whatever the engine
    stacked — int32 on device, sometimes int64/float via numpy on host),
    so host callers can read it with ``np.asarray`` and compare against
    bucket sizes without silent float truncation. Demand can never be
    negative, and T caps each frame's count, so int32 cannot overflow.
    """
    return (jnp.sum(jnp.asarray(active).astype(jnp.int32), axis=-1)
            + jnp.asarray(overflow_tiles).astype(jnp.int32))


def block_loads(plan: TilePlan, num_blocks: int) -> jax.Array:
    """(B,) predicted pairs per LDU block — the FrameRecord load summary."""
    idx = jnp.where(plan.block_of >= 0, plan.block_of, num_blocks)
    wl = jnp.where(plan.slot_active, plan.workload, 0)
    return jnp.zeros((num_blocks,), jnp.int32).at[idx].add(wl, mode="drop")
