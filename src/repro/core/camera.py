"""Pinhole camera model and pose utilities.

Intrinsics and image size are static (python numbers) so they participate in
jit specialization; the world-to-camera pose is a traced (4, 4) array so the
same compiled renderer serves a whole trajectory.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

TILE = 16  # 16x16-pixel tiles, as in the paper (Sec. II-A)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Camera:
    """Pinhole camera. ``w2c`` maps world -> camera (x right, y down, +z fwd)."""

    w2c: jax.Array  # (4, 4)
    fx: float = dataclasses.field(metadata=dict(static=True))
    fy: float = dataclasses.field(metadata=dict(static=True))
    cx: float = dataclasses.field(metadata=dict(static=True))
    cy: float = dataclasses.field(metadata=dict(static=True))
    width: int = dataclasses.field(metadata=dict(static=True))
    height: int = dataclasses.field(metadata=dict(static=True))

    @property
    def tiles_x(self) -> int:
        return self.width // TILE

    @property
    def tiles_y(self) -> int:
        return self.height // TILE

    @property
    def num_tiles(self) -> int:
        return self.tiles_x * self.tiles_y

    def with_pose(self, w2c: jax.Array) -> "Camera":
        return dataclasses.replace(self, w2c=w2c)


def make_camera(w2c, *, width: int, height: int, fov_deg: float = 60.0) -> Camera:
    """Square-pixel camera from a vertical FOV."""
    if width % TILE or height % TILE:
        raise ValueError(f"image size must be a multiple of {TILE}")
    f = 0.5 * height / float(np.tan(np.radians(fov_deg) / 2.0))
    return Camera(w2c=jnp.asarray(w2c, jnp.float32), fx=f, fy=f,
                  cx=width / 2.0, cy=height / 2.0, width=width, height=height)


def look_at(eye, target, up=(0.0, 1.0, 0.0)) -> jax.Array:
    """World-to-camera matrix looking from ``eye`` at ``target``. (4, 4)."""
    eye = jnp.asarray(eye, jnp.float32)
    target = jnp.asarray(target, jnp.float32)
    up = jnp.asarray(up, jnp.float32)
    fwd = target - eye
    fwd = fwd / (jnp.linalg.norm(fwd) + 1e-12)
    right = jnp.cross(fwd, up)
    right = right / (jnp.linalg.norm(right) + 1e-12)
    down = jnp.cross(fwd, right)  # y points down in camera frame
    rot = jnp.stack([right, down, fwd], axis=0)  # (3, 3) world->cam rotation
    trans = -rot @ eye
    w2c = jnp.eye(4, dtype=jnp.float32)
    w2c = w2c.at[:3, :3].set(rot).at[:3, 3].set(trans)
    return w2c


def camera_position(cam: Camera) -> jax.Array:
    """Camera center in world coordinates. (3,)."""
    rot = cam.w2c[:3, :3]
    return -rot.T @ cam.w2c[:3, 3]


def cam_to_world(cam: Camera) -> jax.Array:
    """(4, 4) inverse pose."""
    rot = cam.w2c[:3, :3]
    c2w = jnp.eye(4, dtype=cam.w2c.dtype)
    c2w = c2w.at[:3, :3].set(rot.T).at[:3, 3].set(-rot.T @ cam.w2c[:3, 3])
    return c2w


def pixel_grid(cam: Camera) -> Tuple[jax.Array, jax.Array]:
    """Pixel-center coordinates (u, v), each (H, W)."""
    u = jnp.arange(cam.width, dtype=jnp.float32) + 0.5
    v = jnp.arange(cam.height, dtype=jnp.float32) + 0.5
    return jnp.meshgrid(u, v, indexing="xy")


def backproject(cam: Camera, depth: jax.Array) -> jax.Array:
    """Lift every pixel to world space using per-pixel depth.

    depth: (H, W) positive camera-z depth. Returns (H, W, 3) world points.
    """
    u, v = pixel_grid(cam)
    x = (u - cam.cx) / cam.fx * depth
    y = (v - cam.cy) / cam.fy * depth
    pts_cam = jnp.stack([x, y, depth], axis=-1)            # (H, W, 3)
    rot = cam.w2c[:3, :3]
    return (pts_cam - cam.w2c[:3, 3]) @ rot  # == rot.T @ (p - t), batched


def project(cam: Camera, pts_world: jax.Array):
    """World points -> (u, v, depth). pts_world: (..., 3)."""
    rot, t = cam.w2c[:3, :3], cam.w2c[:3, 3]
    pc = pts_world @ rot.T + t
    z = pc[..., 2]
    safe_z = jnp.where(jnp.abs(z) < 1e-8, 1e-8, z)
    u = cam.fx * pc[..., 0] / safe_z + cam.cx
    v = cam.fy * pc[..., 1] / safe_z + cam.cy
    return u, v, z
