"""LDU — Load Distribution Unit scheduling policies (paper Sec. V-B).

Assigns tiles to the accelerator's B parallel rasterization blocks.

The paper's policy ("ls_gaussian"):
  1. traverse tiles in Morton (Z-order) for spatial/memory locality;
  2. greedy sequential fill: a tile joins the current block unless the
     block's cumulative predicted workload would exceed (1 + 1/N) * W,
     where W = ideal per-block load and N = average tiles per block —
     then it opens the next block;
  3. inside each block, tiles execute light-to-heavy so the (shared,
     serial) sorting unit always finishes a tile's sort before the
     rasterizer drains the previous tile (removes intra-block bubbles).

Baselines: "static_blocked" (contiguous raster-order chunks),
"round_robin" (tile i -> block i mod B), "dynamic" (greedy
shortest-queue, models the GPU hardware scheduler).

All policies are pure functions -> ``Schedule`` (numpy, host-side: this is
control logic that would run on the LDU's tiny scalar core, not on the
datapath).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class Schedule:
    block_of_tile: np.ndarray   # (T,) block id per tile (-1 = not scheduled)
    order_in_block: np.ndarray  # (T,) execution position within its block
    num_blocks: int

    def tiles_of_block(self, b: int) -> np.ndarray:
        ids = np.where(self.block_of_tile == b)[0]
        return ids[np.argsort(self.order_in_block[ids], kind="stable")]


def morton_order(tiles_x: int, tiles_y: int) -> np.ndarray:
    """Tile visit order following the Z-order curve. (T,) tile indices."""
    def interleave(x: np.ndarray) -> np.ndarray:
        x = x.astype(np.uint32)
        x = (x | (x << 8)) & 0x00FF00FF
        x = (x | (x << 4)) & 0x0F0F0F0F
        x = (x | (x << 2)) & 0x33333333
        x = (x | (x << 1)) & 0x55555555
        return x

    ty, tx = np.meshgrid(np.arange(tiles_y), np.arange(tiles_x), indexing="ij")
    code = interleave(tx.ravel()) | (interleave(ty.ravel()) << 1)
    return np.argsort(code, kind="stable")


def schedule(workload: np.ndarray, num_blocks: int, *,
             policy: str = "ls_gaussian",
             tiles_x: Optional[int] = None, tiles_y: Optional[int] = None,
             active: Optional[np.ndarray] = None) -> Schedule:
    """Build a tile->block schedule.

    workload: (T,) predicted pairs per tile (the LDU uses DPES estimates).
    active: optional (T,) bool — only these tiles are scheduled (TWSR
    re-render set); inactive tiles get block -1.
    """
    workload = np.asarray(workload, np.int64)
    t_total = workload.shape[0]
    if active is None:
        active = np.ones((t_total,), bool)
    active = np.asarray(active, bool)
    tile_ids = np.where(active)[0]
    t = len(tile_ids)
    block_of = np.full((t_total,), -1, np.int64)
    order_in = np.zeros((t_total,), np.int64)
    b = max(num_blocks, 1)

    if t == 0:
        return Schedule(block_of, order_in, b)

    if policy == "static_blocked":
        chunk = -(-t // b)
        for i, tid in enumerate(tile_ids):
            block_of[tid] = min(i // chunk, b - 1)
    elif policy == "round_robin":
        for i, tid in enumerate(tile_ids):
            block_of[tid] = i % b
    elif policy == "dynamic":
        # GPU-scheduler model: next tile (raster order) goes to the block
        # with the least accumulated work.
        loads = np.zeros(b)
        for tid in tile_ids:
            j = int(np.argmin(loads))
            block_of[tid] = j
            loads[j] += workload[tid]
    elif policy == "ls_gaussian":
        if tiles_x is None or tiles_y is None:
            raise ValueError("ls_gaussian policy needs tiles_x/tiles_y for "
                             "Morton traversal")
        visit = morton_order(tiles_x, tiles_y)
        visit = visit[active[visit]]
        w_ideal = max(workload[tile_ids].sum() / b, 1.0)
        n_avg = max(t / b, 1.0)
        cap = (1.0 + 1.0 / n_avg) * w_ideal
        # Paper rule: a tile that would push the current block past the cap
        # is "deferred to the next block". Taken literally this strands the
        # overflow of a fragmented traversal in the LAST block; we harden
        # it by deferring cyclically (next block with room, least-loaded as
        # the final fallback) — recorded in DESIGN.md §3.
        accs = np.zeros(b)
        cur = 0
        for tid in visit:
            wl = float(workload[tid])
            if accs[cur] + wl > cap:
                for _ in range(b):
                    cur = (cur + 1) % b
                    if accs[cur] + wl <= cap:
                        break
                else:
                    cur = int(np.argmin(accs))
            block_of[tid] = cur
            accs[cur] += wl
    else:
        raise ValueError(f"unknown policy {policy!r}")

    # Intra-block execution order: the paper's light-to-heavy for
    # ls_gaussian, arrival order otherwise.
    for j in range(b):
        ids = np.where(block_of == j)[0]
        if len(ids) == 0:
            continue
        if policy == "ls_gaussian":
            perm = ids[np.argsort(workload[ids], kind="stable")]
        else:
            perm = ids
        order_in[perm] = np.arange(len(perm))
    return Schedule(block_of, order_in, b)


def load_stats(sched: Schedule, workload: np.ndarray) -> dict:
    """Imbalance diagnostics: per-block totals, max/mean ratio."""
    loads = np.zeros(sched.num_blocks)
    for j in range(sched.num_blocks):
        ids = np.where(sched.block_of_tile == j)[0]
        loads[j] = workload[ids].sum()
    mean = loads.mean() if loads.size else 0.0
    return {
        "block_loads": loads,
        "max_over_mean": float(loads.max() / mean) if mean > 0 else 1.0,
        "cv": float(loads.std() / mean) if mean > 0 else 0.0,
    }
