"""LDU — Load Distribution Unit scheduling policies (paper Sec. V-B).

Assigns tiles to the accelerator's B parallel rasterization blocks.

The paper's policy ("ls_gaussian"):
  1. traverse tiles in Morton (Z-order) for spatial/memory locality;
  2. greedy sequential fill: a tile joins the current block unless the
     block's cumulative predicted workload would exceed (1 + 1/N) * W,
     where W = ideal per-block load and N = average tiles per block —
     then it opens the next block;
  3. inside each block, tiles execute light-to-heavy so the (shared,
     serial) sorting unit always finishes a tile's sort before the
     rasterizer drains the previous tile (removes intra-block bubbles).

Baselines: "static_blocked" (contiguous raster-order chunks),
"round_robin" (tile i -> block i mod B), "dynamic" (greedy
shortest-queue, models the GPU hardware scheduler).

Two implementations live side by side:

- ``schedule`` (numpy, host-side): the original, straightforwardly
  auditable version — kept as the golden reference and used by the
  accelerator simulator's host-side ablations (core/streaming.py).
- ``ldu_schedule`` / ``greedy_fill`` / ``order_within_blocks`` (jnp,
  device-side): the jit-compatible port the plan-driven renderer calls
  *inside* the scanned streaming loop (core/plan.py, core/pipeline.py),
  so every ``FrameRecord`` carries the LDU block assignment with no host
  callback. ``tests/test_load_balance.py`` pins the two implementations
  to bit-identical block assignments across all four policies.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Schedule:
    block_of_tile: np.ndarray   # (T,) block id per tile (-1 = not scheduled)
    order_in_block: np.ndarray  # (T,) execution position within its block
    num_blocks: int

    def tiles_of_block(self, b: int) -> np.ndarray:
        ids = np.where(self.block_of_tile == b)[0]
        return ids[np.argsort(self.order_in_block[ids], kind="stable")]


def morton_order(tiles_x: int, tiles_y: int) -> np.ndarray:
    """Tile visit order following the Z-order curve. (T,) tile indices."""
    def interleave(x: np.ndarray) -> np.ndarray:
        x = x.astype(np.uint32)
        x = (x | (x << 8)) & 0x00FF00FF
        x = (x | (x << 4)) & 0x0F0F0F0F
        x = (x | (x << 2)) & 0x33333333
        x = (x | (x << 1)) & 0x55555555
        return x

    ty, tx = np.meshgrid(np.arange(tiles_y), np.arange(tiles_x), indexing="ij")
    code = interleave(tx.ravel()) | (interleave(ty.ravel()) << 1)
    return np.argsort(code, kind="stable")


def schedule(workload: np.ndarray, num_blocks: int, *,
             policy: str = "ls_gaussian",
             tiles_x: Optional[int] = None, tiles_y: Optional[int] = None,
             active: Optional[np.ndarray] = None) -> Schedule:
    """Build a tile->block schedule.

    workload: (T,) predicted pairs per tile (the LDU uses DPES estimates).
    active: optional (T,) bool — only these tiles are scheduled (TWSR
    re-render set); inactive tiles get block -1.
    """
    workload = np.asarray(workload, np.int64)
    t_total = workload.shape[0]
    if active is None:
        active = np.ones((t_total,), bool)
    active = np.asarray(active, bool)
    tile_ids = np.where(active)[0]
    t = len(tile_ids)
    block_of = np.full((t_total,), -1, np.int64)
    order_in = np.zeros((t_total,), np.int64)
    b = max(num_blocks, 1)

    if t == 0:
        return Schedule(block_of, order_in, b)

    if policy == "static_blocked":
        chunk = -(-t // b)
        for i, tid in enumerate(tile_ids):
            block_of[tid] = min(i // chunk, b - 1)
    elif policy == "round_robin":
        for i, tid in enumerate(tile_ids):
            block_of[tid] = i % b
    elif policy == "dynamic":
        # GPU-scheduler model: next tile (raster order) goes to the block
        # with the least accumulated work.
        loads = np.zeros(b)
        for tid in tile_ids:
            j = int(np.argmin(loads))
            block_of[tid] = j
            loads[j] += workload[tid]
    elif policy == "ls_gaussian":
        if tiles_x is None or tiles_y is None:
            raise ValueError("ls_gaussian policy needs tiles_x/tiles_y for "
                             "Morton traversal")
        visit = morton_order(tiles_x, tiles_y)
        visit = visit[active[visit]]
        w_ideal = max(workload[tile_ids].sum() / b, 1.0)
        n_avg = max(t / b, 1.0)
        cap = (1.0 + 1.0 / n_avg) * w_ideal
        # Paper rule: a tile that would push the current block past the cap
        # is "deferred to the next block". Taken literally this strands the
        # overflow of a fragmented traversal in the LAST block; we harden
        # it by deferring cyclically (next block with room, least-loaded as
        # the final fallback) — recorded in DESIGN.md §3.
        accs = np.zeros(b)
        cur = 0
        for tid in visit:
            wl = float(workload[tid])
            if accs[cur] + wl > cap:
                for _ in range(b):
                    cur = (cur + 1) % b
                    if accs[cur] + wl <= cap:
                        break
                else:
                    cur = int(np.argmin(accs))
            block_of[tid] = cur
            accs[cur] += wl
    else:
        raise ValueError(f"unknown policy {policy!r}")

    # Intra-block execution order: the paper's light-to-heavy for
    # ls_gaussian, arrival order otherwise.
    for j in range(b):
        ids = np.where(block_of == j)[0]
        if len(ids) == 0:
            continue
        if policy == "ls_gaussian":
            perm = ids[np.argsort(workload[ids], kind="stable")]
        else:
            perm = ids
        order_in[perm] = np.arange(len(perm))
    return Schedule(block_of, order_in, b)


# --------------------------------------------------------------------------
# Device-side (jnp) port — runs inside the jitted lax.scan streaming loop.
# --------------------------------------------------------------------------

def morton_rank(tiles_x: int, tiles_y: int) -> jax.Array:
    """(T,) Z-order visit priority per tile id (jnp; constant under jit).

    ``rank[tid]`` is the position of tile ``tid`` along the Morton curve,
    so ``jnp.argsort(rank)`` equals the numpy ``morton_order`` traversal.
    """
    def interleave(x: jax.Array) -> jax.Array:
        x = x.astype(jnp.uint32)
        x = (x | (x << 8)) & jnp.uint32(0x00FF00FF)
        x = (x | (x << 4)) & jnp.uint32(0x0F0F0F0F)
        x = (x | (x << 2)) & jnp.uint32(0x33333333)
        x = (x | (x << 1)) & jnp.uint32(0x55555555)
        return x

    ty, tx = jnp.meshgrid(jnp.arange(tiles_y), jnp.arange(tiles_x),
                          indexing="ij")
    code = interleave(tx.ravel()) | (interleave(ty.ravel()) << 1)
    order = jnp.argsort(code, stable=True)
    t = tiles_x * tiles_y
    return jnp.zeros((t,), jnp.int32).at[order].set(
        jnp.arange(t, dtype=jnp.int32))


def greedy_fill(workload: jax.Array, active: jax.Array,
                num_blocks: int) -> jax.Array:
    """Paper's greedy capacity fill over slots IN ORDER (device scan).

    Callers present slots in the intended traversal order (Morton for the
    plan path). A slot joins the current block unless that would push the
    block past ``(1 + 1/N) * W``; it then defers cyclically to the next
    block with room, falling back to the least-loaded block (the same
    hardened deferral as numpy ``schedule`` — DESIGN.md §3). Inactive
    slots are skipped and get block -1.

    workload: (R,) predicted pairs; active: (R,) bool. Returns (R,) int32.
    """
    b = max(int(num_blocks), 1)
    # Mirror numpy schedule()'s int64 entry cast (truncation included) so
    # the fit decisions below see the same values as the golden reference.
    wl = workload.astype(jnp.int32).astype(jnp.float32)
    act = active.astype(bool)
    n_active = jnp.sum(act.astype(jnp.int32)).astype(jnp.float32)
    total = jnp.sum(jnp.where(act, wl, 0.0))
    w_ideal = jnp.maximum(total / b, 1.0)
    n_avg = jnp.maximum(n_active / b, 1.0)
    cap = (1.0 + 1.0 / n_avg) * w_ideal
    offsets = jnp.arange(b, dtype=jnp.int32)

    def body(carry, x):
        accs, cur = carry
        w, a = x
        fits_cur = accs[cur] + w <= cap
        cand = jnp.mod(cur + 1 + offsets, b)           # cur+1 .. cur+b
        fits = accs[cand] + w <= cap
        deferred = jnp.where(jnp.any(fits), cand[jnp.argmax(fits)],
                             jnp.argmin(accs).astype(jnp.int32))
        tgt = jnp.where(fits_cur, cur, deferred)
        accs = jnp.where(a, accs.at[tgt].add(w), accs)
        new_cur = jnp.where(a, tgt, cur)
        return (accs, new_cur), jnp.where(a, tgt, -1)

    init = (jnp.zeros((b,), jnp.float32), jnp.int32(0))
    _, blocks = jax.lax.scan(body, init, (wl, act))
    return blocks.astype(jnp.int32)


def order_within_blocks(block_of: jax.Array, key: jax.Array,
                        tiebreak: jax.Array) -> jax.Array:
    """(R,) execution position of each slot within its block (device).

    ``key`` is the primary ordering (workload for the paper's
    light-to-heavy rule, visit position for arrival order); ties break on
    ``tiebreak`` (tile id — matching numpy ``schedule``'s stable sorts).
    Slots with block -1 get position 0, like the numpy reference.
    """
    r = block_of.shape[0]
    sort_idx = jnp.lexsort((tiebreak, key, block_of))
    blk_sorted = block_of[sort_idx]
    pos = jnp.arange(r, dtype=jnp.int32)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), blk_sorted[1:] != blk_sorted[:-1]])
    seg_start = jax.lax.cummax(jnp.where(is_start, pos, 0))
    order = jnp.zeros((r,), jnp.int32).at[sort_idx].set(pos - seg_start)
    return jnp.where(block_of >= 0, order, 0)


def ldu_schedule(workload: jax.Array, num_blocks: int, *,
                 policy: str = "ls_gaussian",
                 tiles_x: Optional[int] = None,
                 tiles_y: Optional[int] = None,
                 active: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Device (jnp) port of ``schedule``: same policies, same assignments.

    Returns ``(block_of_tile, order_in_block)``, both (T,) int32, matching
    the numpy golden reference bit-for-bit on identical inputs. Fully
    jit/vmap/scan-compatible — this is what the plan-driven renderer runs
    inside the scanned streaming engine.
    """
    workload = jnp.asarray(workload).astype(jnp.int32)  # numpy entry cast
    t = workload.shape[0]
    b = max(int(num_blocks), 1)
    if active is None:
        active = jnp.ones((t,), bool)
    active = active.astype(bool)
    tile_ids = jnp.arange(t, dtype=jnp.int32)
    pos_active = jnp.cumsum(active.astype(jnp.int32)) - 1
    n_active = jnp.sum(active.astype(jnp.int32))

    if policy == "static_blocked":
        chunk = jnp.maximum((n_active + b - 1) // b, 1)
        blk = jnp.minimum(pos_active // chunk, b - 1)
        block_of = jnp.where(active, blk, -1).astype(jnp.int32)
    elif policy == "round_robin":
        block_of = jnp.where(active, pos_active % b, -1).astype(jnp.int32)
    elif policy == "dynamic":
        def body(loads, x):
            w, a = x
            j = jnp.argmin(loads).astype(jnp.int32)
            loads = jnp.where(a, loads.at[j].add(w), loads)
            return loads, jnp.where(a, j, -1)
        _, block_of = jax.lax.scan(
            body, jnp.zeros((b,), jnp.float32),
            (workload.astype(jnp.float32), active))
        block_of = block_of.astype(jnp.int32)
    elif policy == "ls_gaussian":
        if tiles_x is None or tiles_y is None:
            raise ValueError("ls_gaussian policy needs tiles_x/tiles_y for "
                             "Morton traversal")
        visit = jnp.argsort(morton_rank(tiles_x, tiles_y))
        blk_v = greedy_fill(workload[visit], active[visit], b)
        block_of = jnp.full((t,), -1, jnp.int32).at[visit].set(blk_v)
    else:
        raise ValueError(f"unknown policy {policy!r}")

    key = workload.astype(jnp.int32) if policy == "ls_gaussian" else tile_ids
    order_in = order_within_blocks(block_of, key, tile_ids)
    return block_of, order_in


def load_stats(sched: Schedule, workload: np.ndarray) -> dict:
    """Imbalance diagnostics: per-block totals, max/mean ratio."""
    loads = np.zeros(sched.num_blocks)
    for j in range(sched.num_blocks):
        ids = np.where(sched.block_of_tile == j)[0]
        loads[j] = workload[ids].sum()
    mean = loads.mean() if loads.size else 0.0
    return {
        "block_loads": loads,
        "max_over_mean": float(loads.max() / mean) if mean > 0 else 1.0,
        "cv": float(loads.std() / mean) if mean > 0 else 0.0,
    }
