"""Streaming-accelerator timing model (paper Sec. V, Figs. 14/15, Tab. I).

The ASIC itself (16nm RTL) cannot be synthesized here; what the paper
*evaluates* is its scheduling behaviour — inter-block balance, intra-block
sort/raster overlap, and cross-frame streaming without global sync. Those
are reproduced with a discrete-event model at the unit level:

  CCU  (preprocess)  : ``n_gaussians / ccu_rate`` + stage-2 intersection
                       candidates at ``intersect_rate`` pairs/cycle.
  VTU  (warp)        : ``n_pixels / vtu_rate``; runs in PARALLEL with the
                       CCU (paper Sec. V-A: latency fully hidden) — frame
                       prep ends at max(CCU, VTU).
  GSU  (sort)        : single serial unit, ``pairs / gsu_rate``; serves
                       tiles in the global need-order (position-in-block,
                       then block), which is what makes light-to-heavy
                       intra-block ordering effective.
  VRU  (raster)      : ``num_blocks`` parallel blocks; a tile costs
                       ``pairs / vru_rate + tile_overhead``; a block's next
                       tile starts at max(block free, tile sort done).

Streaming mode lets each unit free-run into the next frame (no global
sync); non-streaming inserts a frame barrier — the difference reproduces
the paper's "streaming pipeline" claim. Unit rates are calibrated so the
relative GSCore-baseline numbers match (see benchmarks/accelerator.py).

This is a host-side analysis tool (pure numpy) — it is the evaluation
harness for the paper's Tables/Figures, not device code.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.load_balance import Schedule, schedule


@dataclasses.dataclass(frozen=True)
class AcceleratorConfig:
    """Unit service rates, calibrated so the relative stage costs match the
    paper's setting: rasterization dominates, per-tile sorting is ~8x
    faster than per-tile rasterization, and the aggregate sorter
    throughput exceeds aggregate VRU consumption (Sec. V-B: "the sorting
    process typically takes less time than rasterization")."""

    num_blocks: int = 32
    ccu_rate: float = 2.0        # gaussians / cycle
    intersect_rate: float = 32.0  # candidate pairs / cycle (stage-2 test)
    gsu_rate: float = 64.0       # pairs / cycle through the (shared) sorter
    vru_rate: float = 1.0        # pairs / cycle / block (256 px lanes)
    vtu_rate: float = 8.0        # pixels / cycle (3 mat-vec muls, pipelined)
    tile_overhead: float = 16.0  # fixed cycles per tile (setup/drain)


@dataclasses.dataclass(frozen=True)
class FrameWork:
    """Workload summary of one frame (from the real pipeline's stats)."""

    n_gaussians: int              # CCU transform work
    candidate_pairs: int          # stage-1 pairs entering the stage-2 test
    raw_pairs: np.ndarray         # (T,) pairs per tile before DPES culling
    sort_pairs: np.ndarray        # (T,) pairs entering sort, post-DPES
    raster_pairs: np.ndarray      # (T,) pairs actually blended (early stop)
    active: np.ndarray            # (T,) bool — tiles that re-render
    n_warp_pixels: int = 0        # VTU work (0 for full frames)
    tiles_x: int = 0
    tiles_y: int = 0
    # Device-LDU schedule recorded by the plan-driven renderer
    # (FrameRecord.block_of_tile / order_in_block); lets the simulator
    # serve exactly what the jitted engine scheduled (policy="recorded")
    # instead of re-deriving it host-side.
    block_of: Optional[np.ndarray] = None       # (T,) int, -1 = unscheduled
    order_in_block: Optional[np.ndarray] = None  # (T,) int
    num_blocks: int = 0           # B the device schedule was built for


def frameworks_from_stacked(records, tiles_x: int, tiles_y: int,
                            n_pixels: int) -> List[FrameWork]:
    """Stacked per-frame record arrays -> per-frame ``FrameWork`` list.

    ``records`` is anything exposing the scanned engine's stacked
    ``FrameRecord`` fields with a leading frame axis ``(F, ...)``
    (``pipeline.StackedRecords`` or the raw stacked NamedTuple). The
    whole trajectory crosses the host boundary in one transfer per
    field, instead of one per frame as with ``List[FrameRecord]``.
    """
    is_full = np.asarray(records.is_full)
    if is_full.ndim != 1:
        raise ValueError(
            f"expected single-trajectory records with (F, ...) fields, got "
            f"is_full shape {is_full.shape}; for multi-stream (B, F, ...) "
            f"records pass one stream at a time, e.g. "
            f"frameworks_from_stacked(StackedRecords(records[i]), ...)")
    n_gaussians = np.asarray(records.n_gaussians)
    candidate = np.asarray(records.candidate_pairs)
    raw = np.asarray(records.raw_pairs)
    sort = np.asarray(records.sort_pairs)
    raster = np.asarray(records.raster_pairs)
    active = np.asarray(records.active)
    block_of = np.asarray(records.block_of_tile)
    order_in = np.asarray(records.order_in_block)
    num_blocks = int(np.asarray(records.block_load).shape[-1])
    return [FrameWork(
        n_gaussians=int(n_gaussians[f]),
        candidate_pairs=int(candidate[f]),
        raw_pairs=raw[f], sort_pairs=sort[f], raster_pairs=raster[f],
        active=active[f],
        n_warp_pixels=0 if is_full[f] else n_pixels,
        tiles_x=tiles_x, tiles_y=tiles_y,
        block_of=block_of[f], order_in_block=order_in[f],
        num_blocks=num_blocks)
        for f in range(is_full.shape[0])]


@dataclasses.dataclass
class FrameTiming:
    prep_end: float
    frame_end: float
    vru_busy: float
    vru_span: float
    utilization: float
    sort_stall: float            # cycles blocks spent waiting on GSU
    idle_stall: float            # inter-block tail idling


def _simulate_raster(work: FrameWork, sched: Schedule,
                     cfg: AcceleratorConfig, prep_end: float,
                     gsu_free: float, vru_free: np.ndarray):
    """Event-driven GSU + VRU simulation for one frame."""
    b = sched.num_blocks
    # Global sort service order: tiles needed earliest first.
    entries = []
    for j in range(b):
        for pos, tid in enumerate(sched.tiles_of_block(j)):
            entries.append((pos, j, tid))
    entries.sort()

    sort_end = {}
    t_gsu = max(gsu_free, prep_end)
    for pos, j, tid in entries:
        t_gsu += float(work.sort_pairs[tid]) / cfg.gsu_rate
        sort_end[tid] = t_gsu

    block_free = vru_free.copy()
    busy = np.zeros(b)
    sort_stall = 0.0
    start_min = np.inf
    for pos, j, tid in entries:
        ready = max(sort_end[tid], prep_end)
        start = max(block_free[j], ready)
        # Intra-block bubble: waiting on the sorter beyond both the block's
        # own availability and frame prep (the paper's "rasterization
        # bubbles", Sec. III Obs. 2).
        sort_stall += max(sort_end[tid] - max(block_free[j], prep_end), 0.0)
        dur = float(work.raster_pairs[tid]) / cfg.vru_rate + cfg.tile_overhead
        block_free[j] = start + dur
        busy[j] += dur
        start_min = min(start_min, start)

    frame_end = float(block_free.max()) if entries else prep_end
    span = frame_end - (start_min if np.isfinite(start_min) else prep_end)
    util = float(busy.sum() / (b * span)) if span > 0 else 1.0
    idle = float((frame_end - block_free).sum()) if entries else 0.0
    return frame_end, t_gsu, block_free, FrameTiming(
        prep_end=prep_end, frame_end=frame_end, vru_busy=float(busy.sum()),
        vru_span=span, utilization=util, sort_stall=sort_stall,
        idle_stall=idle)


def simulate_sequence(frames: Sequence[FrameWork], cfg: AcceleratorConfig,
                      *, policy: str = "ls_gaussian",
                      workload_source: str = "dpes",
                      light_to_heavy: bool = True,
                      streaming: bool = True) -> List[FrameTiming]:
    """Simulate a frame sequence; returns per-frame timings.

    policy/workload_source/light_to_heavy reproduce the paper's ablation:
      - GSCore-like baseline : policy="round_robin", workload_source="raw",
                               light_to_heavy=False
      - + LD1 (inter-block)  : policy="ls_gaussian", light_to_heavy=False
      - + LD2 (intra-block)  : light_to_heavy=True (full LS-Gaussian)
      - recorded             : policy="recorded" — serve the device-LDU
                               schedule the plan-driven renderer recorded
                               in the FrameRecord (no host re-derivation;
                               requires matching cfg.num_blocks)
    """
    timings: List[FrameTiming] = []
    ccu_free = 0.0
    vtu_free = 0.0
    gsu_free = 0.0
    vru_free = np.zeros(cfg.num_blocks)
    frame_barrier = 0.0

    for work in frames:
        ccu_start = max(ccu_free, frame_barrier)
        ccu_end = ccu_start + work.n_gaussians / cfg.ccu_rate \
            + work.candidate_pairs / cfg.intersect_rate
        vtu_start = max(vtu_free, frame_barrier)
        vtu_end = vtu_start + work.n_warp_pixels / cfg.vtu_rate
        prep_end = max(ccu_end, vtu_end)
        ccu_free, vtu_free = ccu_end, vtu_end

        if policy == "recorded":
            if work.block_of is None or work.order_in_block is None:
                raise ValueError(
                    "policy='recorded' needs FrameWork.block_of / "
                    "order_in_block from the plan-driven renderer")
            if work.num_blocks and work.num_blocks != cfg.num_blocks:
                raise ValueError(
                    f"recorded schedule was built for {work.num_blocks} "
                    f"blocks but the simulator has {cfg.num_blocks}")
            if np.max(work.block_of, initial=-1) >= cfg.num_blocks:
                raise ValueError(
                    f"recorded schedule assigns block "
                    f"{int(np.max(work.block_of))} but the simulator only "
                    f"has {cfg.num_blocks} blocks")
            sched = Schedule(
                block_of_tile=np.asarray(work.block_of, np.int64),
                order_in_block=np.asarray(work.order_in_block, np.int64),
                num_blocks=cfg.num_blocks)
        else:
            # Without DPES the LDU only knows raw (pre-cull) pair counts;
            # with it, post-cull counts are an accurate raster predictor.
            wl = work.sort_pairs if workload_source == "dpes" \
                else work.raw_pairs
            sched = schedule(np.asarray(wl), cfg.num_blocks, policy=policy,
                             tiles_x=work.tiles_x, tiles_y=work.tiles_y,
                             active=np.asarray(work.active))
            if policy == "ls_gaussian" and not light_to_heavy:
                # strip the intra-block reordering: arrival (Morton) order
                sched = dataclasses.replace(
                    sched, order_in_block=_arrival_order(sched, work))

        frame_end, gsu_free, vru_free, t = _simulate_raster(
            work, sched, cfg, prep_end, gsu_free, vru_free)
        timings.append(t)
        frame_barrier = frame_end if not streaming else 0.0
        if not streaming:
            # global sync: every unit drains
            ccu_free = vtu_free = gsu_free = frame_end
            vru_free = np.full(cfg.num_blocks, frame_end)
    return timings


def _arrival_order(sched: Schedule, work: FrameWork) -> np.ndarray:
    from repro.core.load_balance import morton_order
    order = np.zeros_like(sched.order_in_block)
    visit = morton_order(work.tiles_x, work.tiles_y)
    for j in range(sched.num_blocks):
        ids = [tid for tid in visit if sched.block_of_tile[tid] == j]
        for pos, tid in enumerate(ids):
            order[tid] = pos
    return order


def throughput(timings: Sequence[FrameTiming],
               num_blocks: Optional[int] = None) -> dict:
    """Steady-state cycles/frame + utilization + stall breakdown.

    Utilization (Tab. I metric) is computed globally: total VRU busy
    cycles over (blocks x wall span of the raster phase), so overlapping
    streaming frames are accounted once.
    """
    if len(timings) < 2:
        span = timings[0].frame_end if timings else 0.0
        n = max(len(timings), 1)
    else:
        span = timings[-1].frame_end - timings[0].frame_end
        n = len(timings) - 1
    busy = float(np.sum([t.vru_busy for t in timings]))
    spans = float(np.sum([t.vru_span for t in timings]))
    b = num_blocks if num_blocks is not None else _infer_blocks(timings)
    return {
        "cycles_per_frame": span / n,
        # Tab. I metric: raster-core busy over (blocks x raster-phase
        # span) — load imbalance + sort bubbles, not other units' time.
        "utilization": busy / (b * spans) if spans > 0 else 1.0,
        "sort_stall": float(np.mean([t.sort_stall for t in timings])),
        "idle_stall": float(np.mean([t.idle_stall for t in timings])),
    }


def _infer_blocks(timings: Sequence[FrameTiming]) -> int:
    # busy <= B * span per frame; tightest bound across frames.
    est = max(int(np.ceil(t.vru_busy / t.vru_span)) if t.vru_span > 0 else 1
              for t in timings)
    return max(est, 1)
