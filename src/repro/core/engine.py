"""On-device scanned streaming engine: one executable per trajectory.

``pipeline.render_trajectory_py`` (the golden reference) is a host-side
Python loop: every frame re-dispatches one of two separately-jitted
functions and appends to Python lists — a per-frame host roundtrip, i.e.
exactly the global-sync barrier the paper's streaming design argues
against. This module folds the whole full/sparse streaming loop into a
single ``lax.scan`` so an entire trajectory compiles ONCE and runs with
no host involvement, and ``jax.vmap``s that scan over a leading stream
axis for batched multi-user serving. Both frame branches are thin
wrappers over the plan-driven ``pipeline.render_planned_frame`` — the
TilePlan construction AND the device-LDU schedule it records run inside
this scan (DESIGN.md §2).

Scan carry layout (``EngineCarry``):

  state     : ``FrameState`` — the reference frame a sparse frame warps
              from (rgb, expected depth, truncated depth, source mask,
              true global frame index). ``state.frame_idx`` carries the
              real frame number: key frames receive it explicitly (a
              mid-trajectory key frame must NOT reset the counter) and
              sparse frames increment it.
  prev_pose : (4, 4) world-to-camera of the previous frame — the warp's
              reference camera (the previous frame is always the
              reference, full or sparse).
  step      : () int32 global frame index, drives the full/sparse
              ``lax.cond``: frame ``f`` is fully rendered when
              ``(f + phase) % window == 0`` (frame 0 is always full —
              there is nothing to warp from).

``phase`` staggers the key-frame schedule between concurrent streams:
with B streams sharing one scene, identical phases would make every
stream pay its expensive full render on the same step (a periodic load
spike B times the steady state). ``stream_phases`` spreads the offsets
so at most ``ceil(B / window)`` streams re-key per step. Caveat: under
``vmap`` the batched ``lax.cond`` lowers to a select, so the XLA
executable runs BOTH branches for every stream at every step — the
stagger does not reduce this process's device FLOPs. What it staggers
is the *recorded workload* (full-render pair counts per step), i.e.
the schedule a real per-stream dispatcher or the accelerator simulator
(core/streaming.py) serves — which is where the serving-load claim
lives and is measured.

Why records became stacked arrays: ``lax.scan`` emits its per-step
outputs as arrays with a leading frame axis ``(F, ...)`` — there is no
Python list to accumulate on device. ``StackedRecords`` (pipeline.py)
wraps that stacked ``FrameRecord`` pytree: benchmarks consume the
``(F, ...)``/``(B, F, ...)`` arrays vectorized (one host transfer per
trajectory instead of one per frame), while ``records[i]`` still
recovers a per-frame ``FrameRecord`` view for spot checks.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp

from repro.core.camera import Camera
from repro.core.pipeline import (FrameRecord, FrameState, RenderConfig,
                                 StackedRecords, TrajectoryResult,
                                 render_full_frame, render_sparse_frame)


class EngineCarry(NamedTuple):
    """Scan state threaded across frames (see module docstring)."""

    state: FrameState       # reference frame for the next warp
    prev_pose: jax.Array    # (4, 4) previous frame's world-to-camera
    step: jax.Array         # () int32 global frame index


class StreamsResult(NamedTuple):
    frames: jax.Array           # (B, F, H, W, 3)
    records: StackedRecords     # fields (B, F, ...)
    phases: jax.Array           # (B,) int32 key-frame phase offsets


def _zero_state(cam: Camera) -> FrameState:
    """Shape/dtype-correct placeholder state for step 0 (always full)."""
    h, w = cam.height, cam.width
    return FrameState(
        rgb=jnp.zeros((h, w, 3), jnp.float32),
        exp_depth=jnp.zeros((h, w), jnp.float32),
        trunc_depth=jnp.zeros((h, w), jnp.float32),
        source_mask=jnp.zeros((h, w), bool),
        frame_idx=jnp.int32(0))


def make_frame_step(scene, cam: Camera, cfg: RenderConfig,
                    phase: jax.Array):
    """Build the unified per-frame transition ``frame_step(carry, pose)``.

    Returns ``(new_carry, (rgb, record))``; full-vs-sparse is a
    ``lax.cond`` on the carried global step, so the function is a valid
    ``lax.scan`` body (and batches under ``vmap`` with per-stream
    ``phase``).
    """

    def frame_step(carry: EngineCarry, pose: jax.Array):
        tgt_cam = cam.with_pose(pose)
        ref_cam = cam.with_pose(carry.prev_pose)

        def full_branch(state: FrameState):
            out, new_state, rec = render_full_frame(
                scene, tgt_cam, cfg, frame_idx=carry.step)
            return out.rgb, new_state, rec

        def sparse_branch(state: FrameState):
            return render_sparse_frame(scene, ref_cam, tgt_cam, state, cfg)

        if cfg.window == 1:
            # Statically always-full: skip compiling the warp branch.
            rgb, new_state, rec = full_branch(carry.state)
        else:
            is_full = (carry.step == 0) | \
                ((carry.step + phase) % cfg.window == 0)
            rgb, new_state, rec = jax.lax.cond(
                is_full, full_branch, sparse_branch, carry.state)
        new_carry = EngineCarry(state=new_state, prev_pose=pose,
                                step=carry.step + 1)
        return new_carry, (rgb, rec)

    return frame_step


def _scan_core(scene, cam: Camera, poses: jax.Array, phase: jax.Array,
               cfg: RenderConfig, keep_states: bool):
    step_fn = make_frame_step(scene, cam, cfg, phase)
    init = EngineCarry(state=_zero_state(cam), prev_pose=poses[0],
                       step=jnp.int32(0))

    def body(carry, pose):
        new_carry, (rgb, rec) = step_fn(carry, pose)
        ys = (rgb, rec, new_carry.state) if keep_states else (rgb, rec)
        return new_carry, ys

    _, ys = jax.lax.scan(body, init, poses)
    return ys


@functools.partial(jax.jit, static_argnames=("cfg", "keep_states"))
def _scan_trajectory(scene, cam, poses, phase, cfg, keep_states):
    return _scan_core(scene, cam, poses, phase, cfg, keep_states)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _scan_streams(scene, cam, poses_batch, phases, cfg):
    fn = lambda poses, phase: _scan_core(scene, cam, poses, phase, cfg,
                                         False)
    return jax.vmap(fn)(poses_batch, phases)


def render_trajectory(scene, cam: Camera, poses: jax.Array,
                      cfg: RenderConfig, *, keep_states: bool = False,
                      phase: Union[int, jax.Array] = 0
                      ) -> TrajectoryResult:
    """Render a pose sequence as ONE jit-compiled ``lax.scan``.

    Numerically matches ``pipeline.render_trajectory_py`` (for
    ``phase=0``) but dispatches a single executable for the whole
    trajectory instead of one per frame.

    poses: (F, 4, 4) world-to-camera per frame. ``phase`` shifts the
    key-frame schedule: frame f is full when (f + phase) % window == 0
    (frame 0 is always full).
    """
    ys = _scan_trajectory(scene, cam, poses, jnp.int32(phase), cfg,
                          keep_states)
    if keep_states:
        frames, recs, states = ys
    else:
        (frames, recs), states = ys, None
    return TrajectoryResult(frames=frames, records=StackedRecords(recs),
                            states=states)


def stream_phases(num_streams: int, window: int) -> jax.Array:
    """(B,) evenly staggered key-frame phase offsets in [0, window)."""
    stride = max(1, window // max(num_streams, 1))
    return (jnp.arange(num_streams, dtype=jnp.int32) * stride) % window


def render_streams(scene, cam: Camera, poses_batch: jax.Array,
                   cfg: RenderConfig, *,
                   phases: Optional[Union[Sequence[int], jax.Array]] = None
                   ) -> StreamsResult:
    """Batched multi-stream rendering: vmap the scanned engine over B
    concurrent camera sessions sharing one scene.

    poses_batch: (B, F, 4, 4). Each stream runs the full streaming loop
    independently (own carry, own key-frame schedule); ``phases``
    (default: ``stream_phases``) staggers the expensive full renders so
    the aggregate *recorded* per-step workload stays flat instead of
    spiking every ``window`` frames (see the module docstring for the
    vmap/select caveat: this vmapped executable itself computes both
    branches per stream regardless of phase).
    """
    b = poses_batch.shape[0]
    if phases is None:
        phases = stream_phases(b, cfg.window)
    phases = jnp.asarray(phases, jnp.int32)
    frames, recs = _scan_streams(scene, cam, poses_batch, phases, cfg)
    return StreamsResult(frames=frames, records=StackedRecords(recs),
                        phases=phases)
