"""On-device scanned streaming engine: one executable per trajectory.

``pipeline.render_trajectory_py`` (the golden reference) is a host-side
Python loop: every frame re-dispatches one of two separately-jitted
functions and appends to Python lists — a per-frame host roundtrip, i.e.
exactly the global-sync barrier the paper's streaming design argues
against. This module folds the whole full/sparse streaming loop into a
single ``lax.scan`` so an entire trajectory compiles ONCE and runs with
no host involvement, and ``jax.vmap``s that scan over a leading stream
axis for batched multi-user serving. Both frame branches are thin
wrappers over the plan-driven ``pipeline.render_planned_frame`` — the
TilePlan construction AND the device-LDU schedule it records run inside
this scan (DESIGN.md §2), and both branches raster through
``RenderConfig.impl`` (DESIGN.md §9: the fused plan-slot Pallas kernel
on TPU backends by default), so every stream and the serve loop inherit
the kernel selection with no engine-level switches.

Scan carry layout (``EngineCarry``):

  state     : ``FrameState`` — the reference frame a sparse frame warps
              from (rgb, expected depth, truncated depth, source mask,
              true global frame index). ``state.frame_idx`` carries the
              real frame number: key frames receive it explicitly (a
              mid-trajectory key frame must NOT reset the counter) and
              sparse frames increment it.
  prev_pose : (4, 4) world-to-camera of the previous frame — the warp's
              reference camera (the previous frame is always the
              reference, full or sparse).
  step      : () int32 global frame index, drives the full/sparse
              ``lax.cond``: frame ``f`` is fully rendered when
              ``(f + phase) % window == 0`` (frame 0 is always full —
              there is nothing to warp from).

``phase`` staggers the key-frame schedule between concurrent streams:
with B streams sharing one scene, identical phases would make every
stream pay its expensive full render on the same step (a periodic load
spike B times the steady state). ``stream_phases`` spreads the offsets
so at most ``ceil(B / window)`` streams re-key per step. Caveat: under
``vmap`` the batched ``lax.cond`` lowers to a select, so the XLA
executable runs BOTH branches for every stream at every step — the
stagger does not reduce this process's device FLOPs. What it staggers
is the *recorded workload* (full-render pair counts per step), i.e.
the schedule a real per-stream dispatcher or the accelerator simulator
(core/streaming.py) serves — which is where the serving-load claim
lives and is measured.

Why records became stacked arrays: ``lax.scan`` emits its per-step
outputs as arrays with a leading frame axis ``(F, ...)`` — there is no
Python list to accumulate on device. ``StackedRecords`` (pipeline.py)
wraps that stacked ``FrameRecord`` pytree: benchmarks consume the
``(F, ...)``/``(B, F, ...)`` arrays vectorized (one host transfer per
trajectory instead of one per frame), while ``records[i]`` still
recovers a per-frame ``FrameRecord`` view for spot checks.

Serving extensions (consumed by ``repro.serve``, DESIGN.md §8): streams
are *resumable* and *ragged*. ``render_streams`` takes per-stream
active-frame ``counts`` (frames past a stream's count are padding: zero
frames, blanked records, and — crucially — a frozen carry whose global
step does not advance, so the key-frame schedule is preserved across
stalls) plus initial ``carries`` (``init_carry``/``init_stream_carries``
for fresh streams), and returns the final carries — a continuous batcher
threads sessions through successive fixed-shape chunks with active
frames bit-identical to a solo run. Streams need not share a scene:
with ``slot_scene`` given, the scene argument is a stacked ``(S, N,
...)`` pytree and each stream gathers its own scene before scanning
(multi-scene serving, DESIGN.md §10).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp

from repro.core.camera import Camera
from repro.core.pipeline import (FrameRecord, FrameState, RenderConfig,
                                 StackedRecords, TrajectoryResult,
                                 contrib_enabled, render_full_frame,
                                 render_sparse_frame)
from repro.obs.trace import annotate


class EngineCarry(NamedTuple):
    """Scan state threaded across frames (see module docstring)."""

    state: FrameState       # reference frame for the next warp
    prev_pose: jax.Array    # (4, 4) previous frame's world-to-camera
    step: jax.Array         # () int32 global frame index


class StreamsResult(NamedTuple):
    frames: jax.Array           # (B, F, H, W, 3)
    records: StackedRecords     # fields (B, F, ...)
    phases: jax.Array           # (B,) int32 key-frame phase offsets
    counts: jax.Array           # (B,) int32 active-frame counts
    frame_active: jax.Array     # (B, F) bool — frame within its count
    carries: EngineCarry        # final per-stream carries, fields (B, ...)


def _zero_state(cam: Camera,
                n_gaussians: Optional[int] = None) -> FrameState:
    """Shape/dtype-correct placeholder state for step 0 (always full).

    ``n_gaussians`` sizes the contribution-prior leaf when the config
    threads it (``pipeline.contrib_enabled``); the inf fill is the
    keep-all prior, and frame 0 is always full so it is never read.
    """
    h, w = cam.height, cam.width
    contrib = None if n_gaussians is None \
        else jnp.full((n_gaussians,), jnp.inf, jnp.float32)
    return FrameState(
        rgb=jnp.zeros((h, w, 3), jnp.float32),
        exp_depth=jnp.zeros((h, w), jnp.float32),
        trunc_depth=jnp.zeros((h, w), jnp.float32),
        source_mask=jnp.zeros((h, w), bool),
        frame_idx=jnp.int32(0),
        contrib=contrib)


def init_carry(cam: Camera, pose: jax.Array,
               n_gaussians: Optional[int] = None) -> EngineCarry:
    """Fresh stream carry: zero state at global step 0 (first frame full).

    ``pose`` seeds ``prev_pose``; frame 0 is always a full render, so the
    warp never reads it — any valid (4, 4) world-to-camera works.
    ``n_gaussians`` (the scene's Gaussian count) is required exactly when
    ``pipeline.contrib_enabled(cfg)`` — it sizes the carried prior so the
    carry's pytree structure matches the scan body's output.
    """
    return EngineCarry(state=_zero_state(cam, n_gaussians),
                       prev_pose=jnp.asarray(pose, jnp.float32),
                       step=jnp.int32(0))


def init_stream_carries(cam: Camera, poses_batch: jax.Array,
                        n_gaussians: Optional[int] = None) -> EngineCarry:
    """Batched fresh carries, fields (B, ...), one per stream slot."""
    return jax.vmap(lambda p: init_carry(cam, p, n_gaussians))(
        poses_batch[:, 0])


def _mask_record(rec: FrameRecord, keep: jax.Array) -> FrameRecord:
    """Blank an inactive (padding) frame's record: zero counts, no active
    tiles, unscheduled LDU blocks — so masked frames read as no work."""
    def m(v, blank):
        return jnp.where(keep, v, jnp.asarray(blank, v.dtype))
    return FrameRecord(
        is_full=m(rec.is_full, False),
        n_gaussians=m(rec.n_gaussians, 0),
        candidate_pairs=m(rec.candidate_pairs, 0),
        raw_pairs=m(rec.raw_pairs, 0),
        sort_pairs=m(rec.sort_pairs, 0),
        raster_pairs=m(rec.raster_pairs, 0),
        active=m(rec.active, False),
        tiles_interpolated=m(rec.tiles_interpolated, 0),
        overflow_pairs=m(rec.overflow_pairs, 0),
        overflow_tiles=m(rec.overflow_tiles, 0),
        block_of_tile=m(rec.block_of_tile, -1),
        order_in_block=m(rec.order_in_block, 0),
        block_load=m(rec.block_load, 0),
        culled_pairs=m(rec.culled_pairs, 0),
        lane_contrib=None if rec.lane_contrib is None
        else m(rec.lane_contrib, 0.0))


def make_frame_step(scene, cam: Camera, cfg: RenderConfig,
                    phase: jax.Array):
    """Build the unified per-frame transition ``frame_step(carry, pose)``.

    Returns ``(new_carry, (rgb, record))``; full-vs-sparse is a
    ``lax.cond`` on the carried global step, so the function is a valid
    ``lax.scan`` body (and batches under ``vmap`` with per-stream
    ``phase``).
    """

    def frame_step(carry: EngineCarry, pose: jax.Array):
        tgt_cam = cam.with_pose(pose)
        ref_cam = cam.with_pose(carry.prev_pose)

        def full_branch(state: FrameState):
            with annotate("repro.frame/full"):
                out, new_state, rec = render_full_frame(
                    scene, tgt_cam, cfg, frame_idx=carry.step)
            return out.rgb, new_state, rec

        def sparse_branch(state: FrameState):
            with annotate("repro.frame/sparse"):
                return render_sparse_frame(scene, ref_cam, tgt_cam, state,
                                           cfg)

        if cfg.window == 1:
            # Statically always-full: skip compiling the warp branch.
            rgb, new_state, rec = full_branch(carry.state)
        else:
            is_full = (carry.step == 0) | \
                ((carry.step + phase) % cfg.window == 0)
            rgb, new_state, rec = jax.lax.cond(
                is_full, full_branch, sparse_branch, carry.state)
        new_carry = EngineCarry(state=new_state, prev_pose=pose,
                                step=carry.step + 1)
        return new_carry, (rgb, rec)

    return frame_step


def _scene_n(scene, cfg: RenderConfig) -> Optional[int]:
    """Gaussian count for carry init, or None when priors are off.

    Works on single (N, ...) and stacked (S, N, ...) scene pytrees."""
    return scene.means.shape[-2] if contrib_enabled(cfg) else None


def _scan_core(scene, cam: Camera, poses: jax.Array, phase: jax.Array,
               cfg: RenderConfig, keep_states: bool):
    step_fn = make_frame_step(scene, cam, cfg, phase)
    init = EngineCarry(state=_zero_state(cam, _scene_n(scene, cfg)),
                       prev_pose=poses[0], step=jnp.int32(0))

    def body(carry, pose):
        new_carry, (rgb, rec) = step_fn(carry, pose)
        ys = (rgb, rec, new_carry.state) if keep_states else (rgb, rec)
        return new_carry, ys

    _, ys = jax.lax.scan(body, init, poses)
    return ys


@functools.partial(jax.jit, static_argnames=("cfg", "keep_states"))
def _scan_trajectory(scene, cam, poses, phase, cfg, keep_states):
    return _scan_core(scene, cam, poses, phase, cfg, keep_states)


def stream_scan(scene, cam: Camera, poses: jax.Array, count: jax.Array,
                phase: jax.Array, cfg: RenderConfig, carry: EngineCarry):
    """Masked, resumable single-stream scan — the serving-layer primitive.

    Renders frames ``0 .. count-1`` of ``poses`` starting from ``carry``
    (use :func:`init_carry` for a fresh stream). Frames at or beyond
    ``count`` are padding: the carry passes through untouched (the global
    step does NOT advance, so the key-frame schedule is preserved across
    stalls), the frame reads as zeros, and the record is blanked via
    ``_mask_record``. Because padded frames always trail the active prefix
    within a chunk, active frames are bit-identical to an unmasked run —
    the serving batcher (repro.serve) relies on this to resume sessions
    chunk by chunk.

    Not jitted here: ``render_streams`` wraps the vmapped version in one
    jit, and ``serve.placement`` shard_maps it across devices.

    Returns ``(carry_end, (frames, records, frame_active))``.
    """
    step_fn = make_frame_step(scene, cam, cfg, phase)

    def body(carry, xs):
        pose, i = xs
        new_carry, (rgb, rec) = step_fn(carry, pose)
        keep = i < count
        carry_out = jax.tree_util.tree_map(
            lambda n, o: jnp.where(keep, n, o), new_carry, carry)
        return carry_out, (jnp.where(keep, rgb, 0.0),
                           _mask_record(rec, keep), keep)

    idx = jnp.arange(poses.shape[0], dtype=jnp.int32)
    return jax.lax.scan(body, carry, (poses, idx))


@functools.partial(jax.jit, static_argnames=("cfg",))
def _scan_streams(scene, cam, poses_batch, counts, phases, carries, cfg):
    fn = lambda poses, count, phase, carry: stream_scan(
        scene, cam, poses, count, phase, cfg, carry)
    return jax.vmap(fn)(poses_batch, counts, phases, carries)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _scan_streams_scenes(scenes, cam, poses_batch, counts, phases, carries,
                         slot_scene, cfg):
    """Multi-scene variant: ``scenes`` fields carry a leading stacked
    scene axis (S, N, ...) and each stream gathers its own scene by
    ``slot_scene`` before running the identical masked scan — so a
    stream's math is value-for-value the same as a single-scene run on
    that scene, and one executable serves any assignment of B streams to
    the S stacked scenes."""
    def fn(poses, count, phase, carry, sid):
        scene = jax.tree_util.tree_map(lambda a: a[sid], scenes)
        return stream_scan(scene, cam, poses, count, phase, cfg, carry)
    return jax.vmap(fn)(poses_batch, counts, phases, carries, slot_scene)


def render_trajectory(scene, cam: Camera, poses: jax.Array,
                      cfg: RenderConfig, *, keep_states: bool = False,
                      phase: Union[int, jax.Array] = 0
                      ) -> TrajectoryResult:
    """Render a pose sequence as ONE jit-compiled ``lax.scan``.

    Numerically matches ``pipeline.render_trajectory_py`` (for
    ``phase=0``) but dispatches a single executable for the whole
    trajectory instead of one per frame.

    poses: (F, 4, 4) world-to-camera per frame. ``phase`` shifts the
    key-frame schedule: frame f is full when (f + phase) % window == 0
    (frame 0 is always full).
    """
    ys = _scan_trajectory(scene, cam, poses, jnp.int32(phase), cfg,
                          keep_states)
    if keep_states:
        frames, recs, states = ys
    else:
        (frames, recs), states = ys, None
    return TrajectoryResult(frames=frames, records=StackedRecords(recs),
                            states=states)


def stream_phases(num_streams: int, window: int) -> jax.Array:
    """(B,) evenly staggered key-frame phase offsets in [0, window)."""
    stride = max(1, window // max(num_streams, 1))
    return (jnp.arange(num_streams, dtype=jnp.int32) * stride) % window


def render_streams(scene, cam: Camera, poses_batch: jax.Array,
                   cfg: RenderConfig, *,
                   phases: Optional[Union[Sequence[int], jax.Array]] = None,
                   counts: Optional[Union[Sequence[int], jax.Array]] = None,
                   carries: Optional[EngineCarry] = None,
                   slot_scene: Optional[Union[Sequence[int],
                                              jax.Array]] = None
                   ) -> StreamsResult:
    """Batched multi-stream rendering: vmap the scanned engine over B
    concurrent camera sessions sharing one scene — or, with
    ``slot_scene``, over B sessions spread across S stacked scenes.

    poses_batch: (B, F, 4, 4). Each stream runs the full streaming loop
    independently (own carry, own key-frame schedule); ``phases``
    (default: ``stream_phases``) staggers the expensive full renders so
    the aggregate *recorded* per-step workload stays flat instead of
    spiking every ``window`` frames (see the module docstring for the
    vmap/select caveat: this vmapped executable itself computes both
    branches per stream regardless of phase).

    ``counts`` (default: all F) gives each stream its own active-frame
    count — trajectories of ragged length ride one fixed-(B, F) batch,
    with frames at or beyond a stream's count masked out (zero frames,
    blanked records, frozen carry). ``carries`` (default: fresh
    :func:`init_carry` per stream) resumes each stream mid-trajectory;
    the final per-stream carries come back in ``StreamsResult.carries``,
    so chunked serving loops (repro.serve.batcher) can thread sessions
    through successive fixed-shape batches.

    ``slot_scene`` (default: None — single shared scene) switches to the
    multi-scene gather path (the serving layer's scene registry,
    DESIGN.md §10): ``scene`` must then be a *stacked* scene pytree with
    fields ``(S, N, ...)`` (e.g. ``serve.scenes.SceneRegistry.stack``)
    and ``slot_scene`` gives each stream slot its scene index in
    ``[0, S)``. Masked (count-0) slots should point at index 0 — they
    trace the render like any slot, so their scene must exist. Because
    the gather happens before the per-stream scan, an active stream is
    value-identical to a single-scene ``render_trajectory`` over its own
    scene (pinned by tests/test_serve_scenes.py).
    """
    b, f = poses_batch.shape[0], poses_batch.shape[1]
    if phases is None:
        phases = stream_phases(b, cfg.window)
    phases = jnp.asarray(phases, jnp.int32)
    if counts is None:
        counts = jnp.full((b,), f, jnp.int32)
    counts = jnp.asarray(counts, jnp.int32)
    if carries is None:
        carries = init_stream_carries(cam, poses_batch,
                                      _scene_n(scene, cfg))
    if slot_scene is not None:
        carry_end, (frames, recs, active) = _scan_streams_scenes(
            scene, cam, poses_batch, counts, phases, carries,
            jnp.asarray(slot_scene, jnp.int32), cfg)
        return StreamsResult(frames=frames, records=StackedRecords(recs),
                             phases=phases, counts=counts,
                             frame_active=active, carries=carry_end)
    carry_end, (frames, recs, active) = _scan_streams(
        scene, cam, poses_batch, counts, phases, carries, cfg)
    return StreamsResult(frames=frames, records=StackedRecords(recs),
                         phases=phases, counts=counts, frame_active=active,
                         carries=carry_end)
