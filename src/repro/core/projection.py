"""Preprocessing stage: cull + project Gaussians to the image plane.

Implements the EWA splatting projection used by 3DGS (Sec. II-A of the
paper): world covariance -> camera -> 2D via the perspective Jacobian,
plus everything TAIT (Sec. IV-C) needs downstream: eigenvalues and
eigenvectors of the 2D covariance, opacity-aware effective radii (eq. 4)
and the tight bounding box (eq. 6).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import gaussians as G
from repro.core.camera import Camera, camera_position

# Opacity threshold below which a Gaussian does not contribute (1/255),
# Sec. II-A / eq. (4).
ALPHA_THRESHOLD = 1.0 / 255.0
# Low-pass dilation added to the projected covariance diagonal, as in the
# reference 3DGS rasterizer (anti-aliasing floor).
COV2D_DILATION = 0.3


class ProjectedGaussians(NamedTuple):
    """Per-Gaussian screen-space quantities (all shape-static, N rows)."""

    mean2d: jax.Array      # (N, 2) pixel coords of projected center
    cov2d: jax.Array       # (N, 3) upper-tri 2D covariance (a, b, c)
    conic: jax.Array       # (N, 3) inverse covariance (A, B, C)
    depth: jax.Array       # (N,)  camera-space z
    rgb: jax.Array         # (N, 3) SH-evaluated color for this view
    opacity: jax.Array     # (N,)
    radius3: jax.Array     # (N,)  classic 3*sqrt(lambda1) radius (baseline AABB)
    eigvals: jax.Array     # (N, 2) (lambda1 >= lambda2) of cov2d
    minor_axis: jax.Array  # (N, 2) unit eigenvector of lambda2 (minor axis dir)
    r_major: jax.Array     # (N,)  TAIT effective semi-major radius, eq. (4)
    r_minor: jax.Array     # (N,)  TAIT effective semi-minor radius, eq. (4)
    tight_half_wh: jax.Array  # (N, 2) TAIT tight bbox half (W/2, H/2), eq. (6)
    valid: jax.Array       # (N,)  in-frustum & non-degenerate & visible


def _eig2x2(a, b, c):
    """Eigen-decomposition of symmetric [[a, b], [b, c]].

    Returns (lam1, lam2, minor_axis) with lam1 >= lam2 and minor_axis the
    unit eigenvector belonging to lam2.
    """
    mid = 0.5 * (a + c)
    half_diff = 0.5 * (a - c)
    disc = jnp.sqrt(jnp.maximum(half_diff * half_diff + b * b, 1e-12))
    lam1 = mid + disc
    lam2 = jnp.maximum(mid - disc, 1e-8)
    # Eigenvector for lam2: (b, lam2 - a) unless b ~ 0.
    ex = jnp.where(jnp.abs(b) > 1e-12, b, jnp.where(a <= c, 1.0, 0.0))
    ey = jnp.where(jnp.abs(b) > 1e-12, lam2 - a, jnp.where(a <= c, 0.0, 1.0))
    norm = jnp.sqrt(ex * ex + ey * ey) + 1e-12
    return lam1, lam2, jnp.stack([ex / norm, ey / norm], axis=-1)


def preprocess(scene: G.GaussianScene, cam: Camera, *,
               near: float = 0.05, frustum_margin: float = 1.3,
               dilation: float = COV2D_DILATION) -> ProjectedGaussians:
    """Project every Gaussian into the view; compute TAIT geometry.

    ``frustum_margin`` widens the cull window (a Gaussian slightly outside
    the image can still splat into it).
    """
    rot, t = cam.w2c[:3, :3], cam.w2c[:3, 3]
    p_cam = scene.means @ rot.T + t                       # (N, 3)
    z = p_cam[..., 2]
    safe_z = jnp.maximum(z, near)

    u = cam.fx * p_cam[..., 0] / safe_z + cam.cx
    v = cam.fy * p_cam[..., 1] / safe_z + cam.cy
    mean2d = jnp.stack([u, v], axis=-1)

    # Perspective Jacobian (2x3) with the standard EWA clamping of x/z, y/z.
    lim_x = frustum_margin * cam.width / (2.0 * cam.fx)
    lim_y = frustum_margin * cam.height / (2.0 * cam.fy)
    tx = jnp.clip(p_cam[..., 0] / safe_z, -lim_x, lim_x) * safe_z
    ty = jnp.clip(p_cam[..., 1] / safe_z, -lim_y, lim_y) * safe_z
    inv_z = 1.0 / safe_z
    inv_z2 = inv_z * inv_z
    zeros = jnp.zeros_like(inv_z)
    j = jnp.stack([
        jnp.stack([cam.fx * inv_z, zeros, -cam.fx * tx * inv_z2], -1),
        jnp.stack([zeros, cam.fy * inv_z, -cam.fy * ty * inv_z2], -1),
    ], axis=-2)                                            # (N, 2, 3)

    cov3d = G.covariances(scene)                           # (N, 3, 3)
    m = j @ rot[None, :, :]                                # (N, 2, 3)
    cov2d_full = m @ cov3d @ jnp.swapaxes(m, -1, -2)       # (N, 2, 2)
    a = cov2d_full[..., 0, 0] + dilation
    b = cov2d_full[..., 0, 1]
    c = cov2d_full[..., 1, 1] + dilation

    det = a * c - b * b
    det_safe = jnp.maximum(det, 1e-12)
    conic = jnp.stack([c / det_safe, -b / det_safe, a / det_safe], axis=-1)

    lam1, lam2, minor_axis = _eig2x2(a, b, c)
    radius3 = jnp.ceil(3.0 * jnp.sqrt(lam1))

    opacity = G.opacities(scene)
    # eq. (4): effective radii where opacity falls to tau = 1/255.
    log_ratio = jnp.log(jnp.maximum(opacity / ALPHA_THRESHOLD, 1.0 + 1e-6))
    r_major = jnp.sqrt(2.0 * log_ratio * lam1)
    r_minor = jnp.sqrt(2.0 * log_ratio * lam2)
    # eq. (6): tight bbox; half-width = sqrt(Sigma'_X / lam1) * R_major etc.
    half_w = jnp.sqrt(jnp.maximum(a / lam1, 0.0)) * r_major
    half_h = jnp.sqrt(jnp.maximum(c / lam1, 0.0)) * r_major
    tight_half_wh = jnp.stack([half_w, half_h], axis=-1)

    cam_pos = camera_position(cam)
    dirs = scene.means - cam_pos
    dirs = dirs / (jnp.linalg.norm(dirs, axis=-1, keepdims=True) + 1e-12)
    rgb = G.eval_sh(scene.sh, dirs)

    in_front = z > near
    visible = opacity > ALPHA_THRESHOLD
    on_screen = ((u + radius3 > 0) & (u - radius3 < cam.width)
                 & (v + radius3 > 0) & (v - radius3 < cam.height))
    valid = in_front & visible & on_screen & (det > 1e-12)

    return ProjectedGaussians(
        mean2d=mean2d, cov2d=jnp.stack([a, b, c], -1), conic=conic,
        depth=z, rgb=rgb, opacity=opacity, radius3=radius3,
        eigvals=jnp.stack([lam1, lam2], -1), minor_axis=minor_axis,
        r_major=r_major, r_minor=r_minor, tight_half_wh=tight_half_wh,
        valid=valid)
