"""TWSR — Tile-Warping-based Sparse Rendering (paper Sec. IV-A, Algo. 1).

Given a reference frame (color + estimated depth + truncated depth + a
source-validity mask), reproject it into the target viewpoint:

  1. ProjectTo3D: back-project every valid reference pixel with its
     estimated scene depth (and, separately, its truncated depth).
  2. ViewTransfer + Reproject: project the point cloud(s) into the target
     camera; z-buffer with a two-pass scatter-min (ties averaged, so the
     result is deterministic).
  3. Per 16x16 tile: count validly reprojected pixels N. If N > N0
     (default 5/6 of the tile, paper Sec. V-A) the tile is *interpolated*
     (missing pixels inpainted from neighbors — preprocess, sort AND raster
     all skipped). Otherwise the tile is queued for full re-rendering and
     its DPES early-stop depth is the max valid reprojected truncated
     depth (Sec. IV-B).
  4. No-cumulative-error mask: interpolated pixels are flagged and excluded
     as sources for the *next* frame's warp ("TW w/ mask", Fig. 7).

Everything is shape-static: tile decisions are boolean masks over the fixed
tile grid, so the whole transform jits and shards — and, because no shape
depends on a traced value, it is a valid ``lax.scan`` body and batches
under ``vmap`` (the scanned engine in core/engine.py relies on both).

The ``rerender_tile`` mask and ``dpes_depth`` priors produced here are the
inputs to ``plan.sparse_plan``: downstream, the re-render set is compacted
into a static-R ``TilePlan`` and rendered through the shared
``pipeline.render_planned_frame`` stage pipeline (DESIGN.md §2).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.camera import TILE, Camera, backproject
from repro.core.raster import tile_view, untile

# A pixel is a usable reprojection source only if enough opacity
# accumulated behind it in the reference render (otherwise its estimated
# depth is meaningless — background / barely-covered pixels).
MIN_COVERAGE = 0.25
# Paper: interpolate when > 5/6 of the tile's pixels arrived.
N0_RATIO = 5.0 / 6.0


class WarpResult(NamedTuple):
    rgb: jax.Array          # (H, W, 3) reprojected color (holes = 0)
    filled: jax.Array       # (H, W) bool — pixel received a source
    exp_depth: jax.Array    # (H, W) reprojected scene depth (holes = 0)
    trunc_depth: jax.Array  # (H, W) reprojected truncated depth (max-scatter)
    valid_per_tile: jax.Array   # (T,) int32 — N in Algo. 1
    interpolate_tile: jax.Array  # (T,) bool — Algo. 1 line 7 branch
    rerender_tile: jax.Array     # (T,) bool
    dpes_depth: jax.Array        # (T,) early-stop depth (inf if unusable)


def _scatter_zbuffer(ti: jax.Array, z: jax.Array, valid: jax.Array,
                     values: jax.Array, size: int):
    """Two-pass deterministic z-buffer scatter.

    ti: (S,) flat target pixel index; z: (S,) depth; valid: (S,) bool;
    values: (S, C). Returns (zmin (size,), out (size, C), hit (size,)).
    Ties within 1e-5 of the winning depth are averaged.
    """
    big = jnp.float32(1e30)
    zs = jnp.where(valid, z, big)
    ti_safe = jnp.where(valid, ti, 0)
    zmin = jnp.full((size,), big).at[ti_safe].min(zs, mode="drop")
    winner = valid & (zs <= zmin[ti_safe] * (1.0 + 1e-5))
    w = winner.astype(jnp.float32)
    cnt = jnp.zeros((size,)).at[ti_safe].add(w, mode="drop")
    acc = jnp.zeros((size, values.shape[-1])).at[ti_safe].add(
        values * w[:, None], mode="drop")
    hit = cnt > 0
    out = acc / jnp.maximum(cnt, 1.0)[:, None]
    return jnp.where(hit, zmin, 0.0), out, hit


def _project_points(ref_cam: Camera, depth_map: jax.Array, mask: jax.Array,
                    tgt_cam: Camera, near: float):
    """Back-project ``depth_map`` and reproject into the target view.

    Returns (ti, z, valid): (S,) flat target pixel index, target-view
    depth, and source validity (mask & in front & in bounds).
    """
    h, w = depth_map.shape
    pts = backproject(ref_cam, depth_map)                   # (H, W, 3)
    rot, t = tgt_cam.w2c[:3, :3], tgt_cam.w2c[:3, 3]
    pc = pts.reshape(-1, 3) @ rot.T + t
    z = pc[:, 2]
    u = tgt_cam.fx * pc[:, 0] / jnp.maximum(z, near) + tgt_cam.cx
    v = tgt_cam.fy * pc[:, 1] / jnp.maximum(z, near) + tgt_cam.cy
    ui = jnp.floor(u).astype(jnp.int32)
    vi = jnp.floor(v).astype(jnp.int32)
    in_bounds = (ui >= 0) & (ui < w) & (vi >= 0) & (vi < h)
    valid = mask.reshape(-1) & (z > near) & in_bounds
    return vi * w + ui, z, valid


def viewpoint_transform(ref_rgb: jax.Array, ref_exp_depth: jax.Array,
                        ref_trunc_depth: jax.Array, ref_source_mask: jax.Array,
                        ref_cam: Camera, tgt_cam: Camera, *,
                        n0_ratio: float = N0_RATIO,
                        near: float = 0.05) -> WarpResult:
    """Algorithm 1 (viewpoint transformation + tile decisions)."""
    h, w = ref_rgb.shape[:2]
    size = h * w

    # --- 1. ProjectTo3D + 2. ViewTransfer/Reproject ----------------------
    ti, z, src_valid = _project_points(ref_cam, ref_exp_depth,
                                       ref_source_mask, tgt_cam, near)

    # Color + the pixel's own scene depth ride the same z-buffer.
    payload = jnp.concatenate(
        [ref_rgb.reshape(-1, 3), ref_exp_depth.reshape(-1, 1)], axis=-1)
    _, out, hit = _scatter_zbuffer(ti, z, src_valid, payload, size)
    rgb_t = out[:, :3].reshape(h, w, 3)
    filled = hit.reshape(h, w)

    # Reprojected scene depth = *target-view* z of the winning source.
    zmap, _, _ = _scatter_zbuffer(ti, z, src_valid,
                                  z[:, None], size)
    exp_depth_t = zmap.reshape(h, w)

    # --- truncated-depth point cloud (separate cloud, max-scatter) -------
    tim_raw, zm, mvalid = _project_points(ref_cam, ref_trunc_depth,
                                          ref_source_mask, tgt_cam, near)
    tim = jnp.where(mvalid, tim_raw, 0)
    trunc_t = jnp.zeros((size,)).at[tim].max(
        jnp.where(mvalid, zm, 0.0), mode="drop").reshape(h, w)

    # --- 3. per-tile decisions (Algo. 1 lines 5-12) ----------------------
    tx, ty = tgt_cam.tiles_x, tgt_cam.tiles_y
    filled_tiles = tile_view(filled[..., None].astype(jnp.int32), tx, ty)
    valid_per_tile = filled_tiles.sum(axis=(1, 2, 3))        # (T,)
    n0 = int(round(n0_ratio * TILE * TILE))
    interpolate_tile = valid_per_tile > n0
    rerender_tile = ~interpolate_tile

    # DPES: early-stop depth = max reprojected truncated depth over the
    # tile's valid pixels; unusable (inf) when nothing valid arrived.
    trunc_tiles = tile_view(trunc_t[..., None], tx, ty)[..., 0]
    tile_max_trunc = jnp.max(trunc_tiles, axis=(1, 2))
    dpes_depth = jnp.where(valid_per_tile > 0, tile_max_trunc, jnp.inf)
    # A re-rendered tile with zero arrivals gives no prior: keep inf.
    dpes_depth = jnp.where(tile_max_trunc > 0, dpes_depth, jnp.inf)

    return WarpResult(rgb=rgb_t, filled=filled, exp_depth=exp_depth_t,
                      trunc_depth=trunc_t, valid_per_tile=valid_per_tile,
                      interpolate_tile=interpolate_tile,
                      rerender_tile=rerender_tile, dpes_depth=dpes_depth)


def inpaint(rgb: jax.Array, filled: jax.Array, *, iters: int = 8) -> jax.Array:
    """Fill holes by iterative 3x3 neighbor averaging (Jacobi diffusion).

    Only missing pixels are written; valid pixels are fixed boundary
    conditions. With <= 1/6 of a tile missing (TW policy) a handful of
    iterations converges.
    """
    f = filled.astype(jnp.float32)[..., None]
    img = rgb * f

    kernel = jnp.ones((3, 3), jnp.float32)

    def blur(x):
        # (H, W, C) -> same, 3x3 box sum with zero padding.
        xp = jnp.pad(x, ((1, 1), (1, 1), (0, 0)))
        s = (xp[:-2, :-2] + xp[:-2, 1:-1] + xp[:-2, 2:]
             + xp[1:-1, :-2] + xp[1:-1, 1:-1] + xp[1:-1, 2:]
             + xp[2:, :-2] + xp[2:, 1:-1] + xp[2:, 2:])
        return s

    def body(_, state):
        img_c, wgt = state
        num = blur(img_c * wgt)
        den = blur(wgt)
        fill_val = num / jnp.maximum(den, 1e-8)
        new_img = jnp.where(filled[..., None], rgb, fill_val)
        new_wgt = jnp.maximum(wgt, (den[..., :1] > 0).astype(jnp.float32))
        return new_img, new_wgt

    img_out, _ = jax.lax.fori_loop(0, iters, body, (img, f))
    return img_out


def pixel_warp_fill(warp: WarpResult, full_rgb: jax.Array) -> jax.Array:
    """PWSR baseline (Potamoi-style): keep every warped pixel, fill only the
    missing ones with freshly rendered values. Quality-only baseline for
    Fig. 7 — it still pays full preprocess+sort (see benchmarks)."""
    return jnp.where(warp.filled[..., None], warp.rgb, full_rgb)
