"""LS-Gaussian end-to-end renderer: full frames + TWSR sparse frames.

The streaming loop (paper Fig. 1): one full render every ``window`` frames;
in between, each frame is produced by viewpoint transformation (warp) +
tile-level decisions — interpolated tiles skip preprocess/sort/raster
entirely, re-rendered tiles go through the pipeline with DPES depth culling.

``render_trajectory`` (core/engine.py) is the production driver — the
whole loop as one jitted ``lax.scan``; ``render_trajectory_py`` below is
the host-side reference loop kept for golden comparison. Per-frame work
summaries (``FrameRecord``) feed both the GPU-style cost model and the
streaming accelerator simulator (core/streaming.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import binning, dpes, intersect, warp as warp_mod
from repro.core.camera import TILE, Camera
from repro.core.projection import preprocess
from repro.core.raster import RenderOutput, render_from_bins, untile
from repro.kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class RenderConfig:
    intersect_method: str = "tait"      # "aabb" | "obb" | "tait" | "exact"
    capacity: int = 512                 # K: max pairs per tile
    chunk: int = 64                     # rasterizer gaussian-chunk
    impl: str = "jnp_chunked"           # "pallas" | "jnp_chunked" | "ref"
    window: int = 5                     # full render every n-th frame
    use_mask: bool = True               # no-cumulative-error mask (Fig. 7)
    use_dpes: bool = True
    dpes_margin: float = 1.0
    n0_ratio: float = warp_mod.N0_RATIO
    inpaint_iters: int = 8
    near: float = 0.05
    min_coverage: float = warp_mod.MIN_COVERAGE
    rerender_capacity: Optional[int] = None  # static cap on re-render tiles


class FrameState(NamedTuple):
    """Reference-frame state carried across the streaming loop."""

    rgb: jax.Array          # (H, W, 3)
    exp_depth: jax.Array    # (H, W)
    trunc_depth: jax.Array  # (H, W)
    source_mask: jax.Array  # (H, W) bool — usable reprojection sources
    frame_idx: jax.Array    # () int32


class FrameRecord(NamedTuple):
    """Per-frame workload summary (device arrays; host converts for sims)."""

    is_full: jax.Array          # () bool
    n_gaussians: jax.Array      # () int32 — valid after frustum cull
    candidate_pairs: jax.Array  # () int32 — pairs entering stage-2 test
    raw_pairs: jax.Array        # (T,) pre-DPES pairs on scheduled tiles
    sort_pairs: jax.Array       # (T,) post-DPES pairs entering sort
    raster_pairs: jax.Array     # (T,) pairs actually traversed
    active: jax.Array           # (T,) bool — re-rendered tiles
    tiles_interpolated: jax.Array  # () int32
    overflow_pairs: jax.Array   # () int32 — bin-capacity overflow
    overflow_tiles: jax.Array   # () int32 — rerender_capacity overflow


def _tile_flag_to_pixels(flag: jax.Array, tiles_x: int, tiles_y: int):
    """(T,) -> (H, W) by broadcasting each flag over its tile."""
    t = flag.shape[0]
    tiles = jnp.broadcast_to(flag[:, None, None], (t, TILE, TILE))
    return untile(tiles, tiles_x, tiles_y)


def render_full_frame(scene, cam: Camera, cfg: RenderConfig
                      ) -> Tuple[RenderOutput, FrameState, FrameRecord]:
    """Key frame: the plain pipeline (preprocess -> TAIT -> sort -> raster)."""
    proj = preprocess(scene, cam, near=cfg.near)
    grid = intersect.make_tile_grid(cam)
    if cfg.intersect_method == "tait":
        stage1 = intersect.tait_stage1_mask(proj, grid)
        mask = intersect.tait_mask(proj, grid)
        candidate_pairs = intersect.pair_count(stage1)
    else:
        mask = intersect.intersect(proj, grid, cfg.intersect_method)
        candidate_pairs = intersect.pair_count(mask)
    bins = binning.build_tile_bins(mask, proj.depth, cfg.capacity)
    out = render_from_bins(proj, bins, grid, impl=cfg.impl, chunk=cfg.chunk)

    coverage = 1.0 - out.transmittance
    state = FrameState(
        rgb=out.rgb, exp_depth=out.exp_depth, trunc_depth=out.trunc_depth,
        source_mask=coverage > cfg.min_coverage,
        frame_idx=jnp.int32(0))
    t = grid.num_tiles
    rec = FrameRecord(
        is_full=jnp.bool_(True),
        n_gaussians=jnp.sum(proj.valid.astype(jnp.int32)),
        candidate_pairs=candidate_pairs,
        raw_pairs=bins.count, sort_pairs=bins.count,
        raster_pairs=out.processed_pairs,
        active=jnp.ones((t,), bool),
        tiles_interpolated=jnp.int32(0),
        overflow_pairs=jnp.sum(bins.overflow),
        overflow_tiles=jnp.int32(0))
    return out, state, rec


def _render_tile_subset(proj, bins: binning.TileBins, grid, rerender,
                        rcap: int, cfg: RenderConfig) -> RenderOutput:
    """Rasterize only the top-``rcap`` re-render tiles; others stay empty."""
    t = grid.num_tiles
    order = jnp.argsort(-rerender.astype(jnp.int32), stable=True)[:rcap]
    sel = rerender[order]                                   # (rcap,)
    sub = binning.TileBins(
        indices=bins.indices[order],
        valid=bins.valid[order] & sel[:, None],
        count=jnp.where(sel, bins.count[order], 0),
        overflow=bins.overflow[order], capacity=bins.capacity)
    tg = binning.gather_tiles(proj, sub)
    rgb_t, trans_t, d_t, td_t, proc = kops.raster_tiles(
        tg.mean2d, tg.conic, tg.rgb, tg.opacity, tg.depth,
        grid.origins[order], sub.count, impl=cfg.impl, chunk=cfg.chunk)
    full = lambda shape, fill: jnp.full(shape, fill, jnp.float32)
    rgb_all = jnp.zeros((t, TILE, TILE, 3)).at[order].set(rgb_t)
    trans_all = full((t, TILE, TILE), 1.0).at[order].set(trans_t)
    d_all = jnp.zeros((t, TILE, TILE)).at[order].set(d_t)
    td_all = jnp.zeros((t, TILE, TILE)).at[order].set(td_t)
    proc_all = jnp.zeros((t,), jnp.int32).at[order].set(proc)
    return RenderOutput(
        rgb=untile(rgb_all, grid.tiles_x, grid.tiles_y),
        transmittance=untile(trans_all, grid.tiles_x, grid.tiles_y),
        exp_depth=untile(d_all, grid.tiles_x, grid.tiles_y),
        trunc_depth=untile(td_all, grid.tiles_x, grid.tiles_y),
        processed_pairs=proc_all)


def render_sparse_frame(scene, ref_cam: Camera, tgt_cam: Camera,
                        state: FrameState, cfg: RenderConfig
                        ) -> Tuple[jax.Array, FrameState, FrameRecord]:
    """TWSR frame (Algo. 1): warp, decide per tile, re-render the rest."""
    w = warp_mod.viewpoint_transform(
        state.rgb, state.exp_depth, state.trunc_depth, state.source_mask,
        ref_cam, tgt_cam, n0_ratio=cfg.n0_ratio, near=cfg.near)
    grid = intersect.make_tile_grid(tgt_cam)

    rerender = w.rerender_tile
    # Optional static cap on the re-render set (wall-clock path): tiles
    # beyond capacity degrade to interpolation and are counted.
    if cfg.rerender_capacity is not None and cfg.rerender_capacity < grid.num_tiles:
        score = rerender.astype(jnp.int32)
        order = jnp.argsort(-score, stable=True)[: cfg.rerender_capacity]
        sel = jnp.zeros((grid.num_tiles,), bool).at[order].set(
            rerender[order])
        overflow_tiles = jnp.sum(rerender) - jnp.sum(sel)
        rerender = sel
    else:
        overflow_tiles = jnp.int32(0)

    proj = preprocess(scene, tgt_cam, near=cfg.near)
    if cfg.intersect_method == "tait":
        stage1 = intersect.tait_stage1_mask(proj, grid)
        mask = intersect.tait_mask(proj, grid)
        candidate_pairs = jnp.sum(
            (stage1 & rerender[None, :]).astype(jnp.int32))
    else:
        mask = intersect.intersect(proj, grid, cfg.intersect_method)
        candidate_pairs = jnp.sum(
            (mask & rerender[None, :]).astype(jnp.int32))
    mask_active = mask & rerender[None, :]
    raw_pairs = jnp.sum(mask_active.astype(jnp.int32), axis=0)

    limit = jnp.where(jnp.isfinite(w.dpes_depth), w.dpes_depth, jnp.inf) \
        if cfg.use_dpes else None
    bins = binning.build_tile_bins(
        mask_active, proj.depth, cfg.capacity,
        depth_limit=limit * cfg.dpes_margin if limit is not None else None)
    if cfg.rerender_capacity is not None \
            and cfg.rerender_capacity < grid.num_tiles:
        # actually SKIP the non-re-rendered tiles: gather the selected
        # tile bins, rasterize only those, scatter back — this is where
        # TWSR's wall-clock win comes from on real hardware.
        out = _render_tile_subset(proj, bins, grid, rerender,
                                  cfg.rerender_capacity, cfg)
    else:
        out = render_from_bins(proj, bins, grid, impl=cfg.impl,
                               chunk=cfg.chunk)

    # --- compose the final frame -----------------------------------------
    # Interpolated tiles: warped pixels + diffusion-inpainted holes; the
    # depth maps ride the same inpainting so chaining stays consistent.
    stacked = jnp.concatenate(
        [w.rgb, w.exp_depth[..., None], w.trunc_depth[..., None]], axis=-1)
    inpainted = warp_mod.inpaint(stacked, w.filled, iters=cfg.inpaint_iters)
    rgb_warp = inpainted[..., :3]
    depth_warp = inpainted[..., 3]
    trunc_warp = inpainted[..., 4]

    rr_px = _tile_flag_to_pixels(rerender, grid.tiles_x, grid.tiles_y)
    rgb_final = jnp.where(rr_px[..., None], out.rgb, rgb_warp)
    exp_depth = jnp.where(rr_px, out.exp_depth, depth_warp)
    trunc_depth = jnp.where(rr_px, out.trunc_depth, trunc_warp)

    # --- next-frame source mask (the "TW w/ mask" mechanism) -------------
    coverage_ok = (1.0 - out.transmittance) > cfg.min_coverage
    interpolated_px = (~rr_px) & (~w.filled)
    if cfg.use_mask:
        src = jnp.where(rr_px, coverage_ok, w.filled)
    else:
        src = jnp.where(rr_px, coverage_ok,
                        w.filled | interpolated_px)
    new_state = FrameState(rgb=rgb_final, exp_depth=exp_depth,
                           trunc_depth=trunc_depth, source_mask=src,
                           frame_idx=state.frame_idx + 1)
    rec = FrameRecord(
        is_full=jnp.bool_(False),
        n_gaussians=jnp.sum(proj.valid.astype(jnp.int32)),
        candidate_pairs=candidate_pairs,
        raw_pairs=raw_pairs, sort_pairs=bins.count,
        raster_pairs=out.processed_pairs,
        active=rerender,
        tiles_interpolated=jnp.sum(w.interpolate_tile.astype(jnp.int32)),
        overflow_pairs=jnp.sum(bins.overflow),
        overflow_tiles=overflow_tiles)
    return rgb_final, new_state, rec


class StackedRecords:
    """Scan-stacked per-frame records.

    Every ``FrameRecord`` field carries a leading frame axis ``(F, ...)``
    (or ``(B, F, ...)`` for multi-stream renders) — the natural output
    layout of ``lax.scan``, and one host transfer per trajectory instead
    of one per frame. Attribute access returns the stacked array
    (``records.raster_pairs`` -> ``(F, T)``); indexing recovers a
    per-frame ``FrameRecord`` view (``records[i].raster_pairs`` ->
    ``(T,)``).
    """

    __slots__ = ("stacked",)

    def __init__(self, stacked: FrameRecord):
        self.stacked = stacked

    @classmethod
    def from_list(cls, records: Sequence[FrameRecord]) -> "StackedRecords":
        return cls(jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *records))

    def __len__(self) -> int:
        return int(self.stacked.is_full.shape[0])

    def __getitem__(self, i) -> FrameRecord:
        return jax.tree_util.tree_map(lambda a: a[i], self.stacked)

    def __iter__(self):
        for i in range(len(self)):
            yield self[i]

    def __getattr__(self, name):
        return getattr(self.stacked, name)


class TrajectoryResult(NamedTuple):
    frames: jax.Array              # (F, H, W, 3)
    records: StackedRecords
    states: Optional[FrameState]   # stacked (F, ...) when keep_states


def render_trajectory(scene, cam: Camera, poses: jax.Array,
                      cfg: RenderConfig, *, keep_states: bool = False,
                      phase: Union[int, jax.Array] = 0
                      ) -> TrajectoryResult:
    """Render a pose sequence with the LS-Gaussian streaming loop.

    Delegates to the scanned engine (core/engine.py): the full/sparse
    loop compiles to ONE executable with no per-frame host dispatch.
    poses: (F, 4, 4) world-to-camera per frame. Frame f is fully rendered
    when (f + phase) % cfg.window == 0, warped otherwise.
    """
    from repro.core import engine  # local import: engine builds on us
    return engine.render_trajectory(scene, cam, poses, cfg,
                                    keep_states=keep_states, phase=phase)


@functools.lru_cache(maxsize=16)
def _legacy_frame_fns(cfg: RenderConfig):
    """Per-config jitted frame functions for the legacy loop. Cached so
    repeated calls (and wall-clock timings) hit warm jit caches instead
    of re-tracing fresh wrappers every trajectory."""
    return (jax.jit(functools.partial(render_full_frame, cfg=cfg)),
            jax.jit(functools.partial(render_sparse_frame, cfg=cfg)))


def render_trajectory_py(scene, cam: Camera, poses: jax.Array,
                         cfg: RenderConfig, *, keep_states: bool = False
                         ) -> TrajectoryResult:
    """Legacy host-side driver: one jitted dispatch per frame.

    Kept as the golden reference for the scanned engine (it is the
    original, straightforwardly-auditable loop). Frame f is fully
    rendered when f % cfg.window == 0, warped otherwise.
    """
    full_fn, sparse_fn = _legacy_frame_fns(cfg)

    frames, records, states = [], [], []
    state = None
    ref_cam = None
    for f in range(poses.shape[0]):
        cam_f = cam.with_pose(poses[f])
        if f % cfg.window == 0 or state is None:
            out, state, rec = full_fn(scene, cam_f)
            frames.append(out.rgb)
        else:
            rgb, state, rec = sparse_fn(scene, ref_cam, cam_f, state)
            frames.append(rgb)
        ref_cam = cam_f
        records.append(rec)
        if keep_states:
            states.append(state)
    stacked_states = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *states) if keep_states else None
    return TrajectoryResult(frames=jnp.stack(frames),
                            records=StackedRecords.from_list(records),
                            states=stacked_states)
