"""LS-Gaussian end-to-end renderer: plan-driven full + TWSR sparse frames.

The streaming loop (paper Fig. 1): one full render every ``window`` frames;
in between, each frame is produced by viewpoint transformation (warp) +
tile-level decisions — interpolated tiles skip preprocess/sort/raster
entirely, re-rendered tiles go through the pipeline with DPES depth culling.

Every frame renders through ONE shared stage pipeline,
``render_planned_frame``: preprocess -> plan-masked intersect -> (R, K)
compacted binning with DPES limits -> device-LDU schedule -> raster over
the plan's R slots -> scatter back to the full frame. Full frames carry an
all-tiles ``TilePlan`` (R = T); TWSR frames carry the warp-predicted
re-render set compacted to ``R = rerender_capacity`` — so sparse-frame
intersect/bin/sort/raster cost all scale with R instead of T (DESIGN.md
§2). ``render_full_frame`` / ``render_sparse_frame`` are thin wrappers.

``render_trajectory`` (core/engine.py) is the production driver — the
whole loop as one jitted ``lax.scan``; ``render_trajectory_py`` below is
the host-side reference loop kept for golden comparison. Per-frame work
summaries (``FrameRecord``) — including the device-LDU block assignments
and per-block load summaries — feed both the GPU-style cost model and the
streaming accelerator simulator (core/streaming.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import binning, culling, intersect, warp as warp_mod
from repro.core import plan as plan_mod
from repro.core.camera import TILE, Camera
from repro.core.plan import TilePlan
from repro.core.projection import preprocess
from repro.core.raster import RenderOutput, render_plan_slots, untile
from repro.kernels.ops import default_impl
from repro.obs.trace import annotate


@dataclasses.dataclass(frozen=True)
class RenderConfig:
    intersect_method: str = "tait"      # "aabb" | "obb" | "tait" | "exact"
    capacity: int = 512                 # K: max pairs per tile
    chunk: int = 64                     # rasterizer gaussian-chunk
    # Raster kernel selection (DESIGN.md §9): "pallas_fused" (the fused
    # plan-slot sort+raster kernel — default on TPU) | "pallas" |
    # "jnp_chunked" (default elsewhere) | "ref".
    impl: str = dataclasses.field(default_factory=default_impl)
    window: int = 5                     # full render every n-th frame
    use_mask: bool = True               # no-cumulative-error mask (Fig. 7)
    use_dpes: bool = True
    dpes_margin: float = 1.0
    n0_ratio: float = warp_mod.N0_RATIO
    inpaint_iters: int = 8
    near: float = 0.05
    min_coverage: float = warp_mod.MIN_COVERAGE
    rerender_capacity: Optional[int] = None  # R: static cap on plan slots
    ldu_blocks: int = 32                # B: parallel raster blocks (LDU)
    # Temporal contribution culling (core/culling.py, DESIGN.md §12): on
    # sparse frames, drop intersection pairs whose Gaussian contributed
    # < cull_threshold blend mass at the last key frame, before binning.
    # 0.0 = the pass is structurally skipped (bit-exact baseline).
    cull_threshold: float = 0.0
    # Populate FrameRecord.lane_contrib / FrameState.contrib even with
    # culling off (e.g. to inspect the 0.0 baseline's statistics). The
    # machinery is always on when cull_threshold > 0.
    record_contrib: bool = False


def contrib_enabled(cfg: RenderConfig) -> bool:
    """Static switch: is the contribution/prior machinery threaded?

    When False (the default), ``FrameState.contrib``,
    ``PlanStats.gauss_prior`` and ``FrameRecord.lane_contrib`` stay
    ``None`` — absent from the pytree — so carries, records and compiled
    executables are structurally identical to the pre-culling pipeline.
    """
    return cfg.cull_threshold > 0.0 or cfg.record_contrib


class FrameState(NamedTuple):
    """Reference-frame state carried across the streaming loop."""

    rgb: jax.Array          # (H, W, 3)
    exp_depth: jax.Array    # (H, W)
    trunc_depth: jax.Array  # (H, W)
    source_mask: jax.Array  # (H, W) bool — usable reprojection sources
    frame_idx: jax.Array    # () int32 — true global frame index
    # Key-frame per-Gaussian contribution prior (inf = not considered at
    # the key frame). None unless ``contrib_enabled(cfg)`` — a None leaf
    # vanishes from the pytree, keeping default-path carries unchanged.
    contrib: Optional[jax.Array] = None  # (N,) float32


class FrameRecord(NamedTuple):
    """Per-frame workload summary (device arrays; host converts for sims)."""

    is_full: jax.Array          # () bool
    n_gaussians: jax.Array      # () int32 — valid after frustum cull
    candidate_pairs: jax.Array  # () int32 — pairs entering stage-2 test
    raw_pairs: jax.Array        # (T,) pre-DPES pairs on scheduled tiles
    sort_pairs: jax.Array       # (T,) post-DPES pairs entering sort
    raster_pairs: jax.Array     # (T,) pairs actually traversed
    active: jax.Array           # (T,) bool — re-rendered tiles
    tiles_interpolated: jax.Array  # () int32
    overflow_pairs: jax.Array   # () int32 — bin-capacity overflow
    overflow_tiles: jax.Array   # () int32 — rerender_capacity overflow
    block_of_tile: jax.Array    # (T,) int32 — device-LDU block (-1 = none)
    order_in_block: jax.Array   # (T,) int32 — light-to-heavy position
    block_load: jax.Array       # (B,) int32 — predicted pairs per block
    culled_pairs: jax.Array     # () int32 — pairs removed by culling
    # Per-(tile, lane) blend contribution in bin lane order (DESIGN.md
    # §12); None unless ``contrib_enabled(cfg)``.
    lane_contrib: Optional[jax.Array] = None  # (T, K) float32


class PlanStats(NamedTuple):
    """Per-slot counters from the shared stage pipeline (R-shaped)."""

    candidate_pairs: jax.Array  # () int32 — stage-2 candidates on the plan
    raw_slots: jax.Array        # (R,) pre-DPES pairs per slot
    overflow_pairs: jax.Array   # () int32 — bin-capacity overflow
    culled_pairs: jax.Array     # () int32 — pairs removed by culling
    # Per-Gaussian contribution with inf where not considered — what key
    # frames store as FrameState.contrib. None unless contrib_enabled.
    gauss_prior: Optional[jax.Array] = None  # (N,) float32


def _tile_flag_to_pixels(flag: jax.Array, tiles_x: int, tiles_y: int):
    """(T,) -> (H, W) by broadcasting each flag over its tile."""
    t = flag.shape[0]
    tiles = jnp.broadcast_to(flag[:, None, None], (t, TILE, TILE))
    return untile(tiles, tiles_x, tiles_y)


def render_planned_frame(scene, cam: Camera, plan: TilePlan,
                         cfg: RenderConfig, *,
                         dpes_depth: Optional[jax.Array] = None,
                         cull_prior: Optional[jax.Array] = None,
                         cull_gate: Optional[jax.Array] = None
                         ) -> Tuple[RenderOutput, TilePlan, "jax.Array",
                                    PlanStats]:
    """The ONE shared stage pipeline every frame renders through.

    preprocess -> intersect against the plan's R slots -> contribution
    cull -> (R, K) compacted binning (with per-slot DPES depth limits) ->
    device-LDU schedule over the slots -> raster the slots -> scatter
    back to the (H, W) frame.

    dpes_depth: optional (T,) per-tile early-stop depth (inf = no prior);
    gathered to the plan's slots before binning.

    cull_prior: optional (N,) key-frame contribution prior (inf = not
    considered); with ``cfg.cull_threshold > 0`` low-contribution pairs
    are removed before binning in slots passed by ``cull_gate`` ((T,)
    bool, default all-True), and fully-culled slots are demoted to
    interpolation (core/culling.py). With the default threshold 0.0 the
    pass is structurally absent and the pipeline is bit-exact with the
    pre-culling code.

    Returns ``(out, plan, n_gaussians, stats)`` where ``out`` is the
    full-frame RenderOutput (unplanned tiles empty), ``plan`` now carries
    the LDU schedule + per-slot workloads, and ``stats`` the remaining
    per-slot counters the wrappers fold into a ``FrameRecord``.
    """
    with annotate("repro.frame/preprocess"):
        proj = preprocess(scene, cam, near=cfg.near)
        grid = intersect.make_tile_grid(cam)
        slots = intersect.take_tiles(grid, plan.tile_ids)

    with annotate("repro.frame/intersect"):
        if cfg.intersect_method == "tait":
            stage1 = intersect.tait_stage1_mask(proj, slots)
            mask = intersect.tait_mask(proj, slots)
            cand_src = stage1
        else:
            mask = intersect.intersect(proj, slots, cfg.intersect_method)
            cand_src = mask
        candidate_pairs = jnp.sum(
            (cand_src & plan.slot_active[None, :]).astype(jnp.int32))
        mask = mask & plan.slot_active[None, :]
    with annotate("repro.frame/cull"):
        if cfg.cull_threshold > 0.0 and cull_prior is not None:
            gate = cull_gate if cull_gate is not None \
                else jnp.ones((cam.num_tiles,), bool)
            mask, slot_active, culled_pairs = culling.cull_pairs(
                mask, plan.slot_active, plan.tile_ids, cull_prior, gate,
                cfg.cull_threshold)
            plan = plan._replace(slot_active=slot_active)
        else:
            culled_pairs = jnp.int32(0)
        raw_slots = jnp.sum(mask.astype(jnp.int32), axis=0)

    with annotate("repro.frame/bin"):
        limit = None
        if dpes_depth is not None:
            limit = dpes_depth[plan.tile_ids] * cfg.dpes_margin
        bins = binning.build_tile_bins(mask, proj.depth, cfg.capacity,
                                       depth_limit=limit)
    # Device LDU (paper Sec. V-B): post-DPES counts are the workload
    # prediction; the greedy Morton fill + light-to-heavy order runs in
    # jnp, inside whatever jit/scan wraps this frame.
    with annotate("repro.frame/ldu_schedule"):
        plan = plan_mod.schedule_plan(plan, bins.count, cfg.ldu_blocks)

    with annotate("repro.frame/raster"):
        out = render_plan_slots(proj, bins, slots.origins, plan.tile_ids,
                                grid, impl=cfg.impl, chunk=cfg.chunk,
                                slot_active=plan.slot_active)
    gauss_prior = None
    if contrib_enabled(cfg):
        # A Gaussian was "considered" if it occupies a valid bin lane
        # anywhere on the plan; everyone else gets inf (= always keep) so
        # Gaussians outside this frame's view are never culled later.
        n = proj.depth.shape[0]
        considered = jnp.zeros((n,), jnp.int32).at[bins.indices].add(
            bins.valid.astype(jnp.int32)) > 0
        gauss_prior = jnp.where(considered, out.gauss_contrib, jnp.inf)
    stats = PlanStats(candidate_pairs=candidate_pairs, raw_slots=raw_slots,
                      overflow_pairs=jnp.sum(bins.overflow),
                      culled_pairs=culled_pairs, gauss_prior=gauss_prior)
    n_gaussians = jnp.sum(proj.valid.astype(jnp.int32))
    return out, plan, n_gaussians, stats


def _plan_record(plan: TilePlan, stats: PlanStats, out: RenderOutput,
                 n_gaussians: jax.Array, num_tiles: int, cfg: RenderConfig,
                 *, is_full: bool, tiles_interpolated: jax.Array
                 ) -> FrameRecord:
    """Fold plan-slot counters into the (T,)-shaped FrameRecord."""
    scat = functools.partial(plan_mod.scatter_slots, plan,
                             num_tiles=num_tiles)
    return FrameRecord(
        is_full=jnp.bool_(is_full),
        n_gaussians=n_gaussians,
        candidate_pairs=stats.candidate_pairs,
        raw_pairs=scat(stats.raw_slots),
        sort_pairs=scat(plan.workload),
        raster_pairs=out.processed_pairs,
        active=scat(plan.slot_active, fill=False),
        tiles_interpolated=tiles_interpolated,
        overflow_pairs=stats.overflow_pairs,
        overflow_tiles=plan.overflow_tiles,
        block_of_tile=scat(plan.block_of, fill=-1),
        order_in_block=scat(plan.order_in_block),
        block_load=plan_mod.block_loads(plan, cfg.ldu_blocks),
        culled_pairs=stats.culled_pairs,
        # Slot-shaped (R, K) from render_plan_slots -> (T, K) per-tile;
        # gated so the dense view only exists when the record wants it
        # (sparse compiles stay plan-shaped otherwise).
        lane_contrib=scat(out.lane_contrib) if contrib_enabled(cfg)
        else None)


def render_full_frame(scene, cam: Camera, cfg: RenderConfig,
                      frame_idx: Union[int, jax.Array] = 0
                      ) -> Tuple[RenderOutput, FrameState, FrameRecord]:
    """Key frame: ``render_planned_frame`` with an all-tiles plan (R = T).

    ``frame_idx`` is the frame's true global index — mid-trajectory key
    frames must not reset the carried counter (it threads through
    ``FrameState`` for the engine's golden comparison).
    """
    tplan = plan_mod.full_plan(cam.tiles_x, cam.tiles_y)
    out, tplan, n_gaussians, stats = render_planned_frame(
        scene, cam, tplan, cfg)

    coverage = 1.0 - out.transmittance
    state = FrameState(
        rgb=out.rgb, exp_depth=out.exp_depth, trunc_depth=out.trunc_depth,
        source_mask=coverage > cfg.min_coverage,
        frame_idx=jnp.asarray(frame_idx, jnp.int32),
        contrib=stats.gauss_prior)
    rec = _plan_record(tplan, stats, out, n_gaussians, cam.num_tiles, cfg,
                       is_full=True, tiles_interpolated=jnp.int32(0))
    return out, state, rec


def render_sparse_frame(scene, ref_cam: Camera, tgt_cam: Camera,
                        state: FrameState, cfg: RenderConfig
                        ) -> Tuple[jax.Array, FrameState, FrameRecord]:
    """TWSR frame (Algo. 1): warp, plan the re-render set, render the plan.

    The warp's tile decisions become a compacted ``TilePlan`` with
    ``R = rerender_capacity`` slots (or R = T when uncapped); re-render
    tiles beyond R degrade to interpolation and are counted.
    """
    with annotate("repro.frame/warp"):
        w = warp_mod.viewpoint_transform(
            state.rgb, state.exp_depth, state.trunc_depth,
            state.source_mask, ref_cam, tgt_cam, n0_ratio=cfg.n0_ratio,
            near=cfg.near)
        tplan = plan_mod.sparse_plan(w.rerender_tile, tgt_cam.tiles_x,
                                     tgt_cam.tiles_y,
                                     cfg.rerender_capacity)

    limit = jnp.where(jnp.isfinite(w.dpes_depth), w.dpes_depth, jnp.inf) \
        if cfg.use_dpes else None
    gate = culling.warp_gate(w.valid_per_tile) \
        if cfg.cull_threshold > 0.0 else None
    out, tplan, n_gaussians, stats = render_planned_frame(
        scene, tgt_cam, tplan, cfg, dpes_depth=limit,
        cull_prior=state.contrib, cull_gate=gate)
    # Effective re-render set: plan slots that survived compaction.
    rerender = plan_mod.scatter_slots(tplan, tplan.slot_active,
                                      num_tiles=tgt_cam.num_tiles,
                                      fill=False)

    # --- compose the final frame -----------------------------------------
    # Interpolated tiles: warped pixels + diffusion-inpainted holes; the
    # depth maps ride the same inpainting so chaining stays consistent.
    with annotate("repro.frame/compose"):
        stacked = jnp.concatenate(
            [w.rgb, w.exp_depth[..., None], w.trunc_depth[..., None]],
            axis=-1)
        inpainted = warp_mod.inpaint(stacked, w.filled,
                                     iters=cfg.inpaint_iters)
        rgb_warp = inpainted[..., :3]
        depth_warp = inpainted[..., 3]
        trunc_warp = inpainted[..., 4]

        rr_px = _tile_flag_to_pixels(rerender, tgt_cam.tiles_x,
                                     tgt_cam.tiles_y)
        rgb_final = jnp.where(rr_px[..., None], out.rgb, rgb_warp)
        exp_depth = jnp.where(rr_px, out.exp_depth, depth_warp)
        trunc_depth = jnp.where(rr_px, out.trunc_depth, trunc_warp)

    # --- next-frame source mask (the "TW w/ mask" mechanism) -------------
    coverage_ok = (1.0 - out.transmittance) > cfg.min_coverage
    interpolated_px = (~rr_px) & (~w.filled)
    if cfg.use_mask:
        src = jnp.where(rr_px, coverage_ok, w.filled)
    else:
        src = jnp.where(rr_px, coverage_ok,
                        w.filled | interpolated_px)
    # Priors refresh only at key frames; sparse frames carry them through.
    new_state = FrameState(rgb=rgb_final, exp_depth=exp_depth,
                           trunc_depth=trunc_depth, source_mask=src,
                           frame_idx=state.frame_idx + 1,
                           contrib=state.contrib)
    rec = _plan_record(
        tplan, stats, out, n_gaussians, tgt_cam.num_tiles, cfg,
        is_full=False,
        tiles_interpolated=jnp.sum(w.interpolate_tile.astype(jnp.int32)))
    return rgb_final, new_state, rec


class StackedRecords:
    """Scan-stacked per-frame records.

    Every ``FrameRecord`` field carries a leading frame axis ``(F, ...)``
    (or ``(B, F, ...)`` for multi-stream renders) — the natural output
    layout of ``lax.scan``, and one host transfer per trajectory instead
    of one per frame. Attribute access returns the stacked array
    (``records.raster_pairs`` -> ``(F, T)``); indexing recovers a
    per-frame ``FrameRecord`` view (``records[i].raster_pairs`` ->
    ``(T,)``).
    """

    __slots__ = ("stacked",)

    def __init__(self, stacked: FrameRecord):
        self.stacked = stacked

    @classmethod
    def from_list(cls, records: Sequence[FrameRecord]) -> "StackedRecords":
        return cls(jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *records))

    def __len__(self) -> int:
        return int(self.stacked.is_full.shape[0])

    def __getitem__(self, i) -> FrameRecord:
        return jax.tree_util.tree_map(lambda a: a[i], self.stacked)

    def __iter__(self):
        for i in range(len(self)):
            yield self[i]

    def __getattr__(self, name):
        return getattr(self.stacked, name)


class TrajectoryResult(NamedTuple):
    frames: jax.Array              # (F, H, W, 3)
    records: StackedRecords
    states: Optional[FrameState]   # stacked (F, ...) when keep_states


def render_trajectory(scene, cam: Camera, poses: jax.Array,
                      cfg: RenderConfig, *, keep_states: bool = False,
                      phase: Union[int, jax.Array] = 0
                      ) -> TrajectoryResult:
    """Render a pose sequence with the LS-Gaussian streaming loop.

    Delegates to the scanned engine (core/engine.py): the full/sparse
    loop compiles to ONE executable with no per-frame host dispatch.
    poses: (F, 4, 4) world-to-camera per frame. Frame f is fully rendered
    when (f + phase) % cfg.window == 0, warped otherwise.
    """
    from repro.core import engine  # local import: engine builds on us
    return engine.render_trajectory(scene, cam, poses, cfg,
                                    keep_states=keep_states, phase=phase)


@functools.lru_cache(maxsize=16)
def _legacy_frame_fns(cfg: RenderConfig):
    """Per-config jitted frame functions for the legacy loop. Cached so
    repeated calls (and wall-clock timings) hit warm jit caches instead
    of re-tracing fresh wrappers every trajectory."""
    return (jax.jit(functools.partial(render_full_frame, cfg=cfg)),
            jax.jit(functools.partial(render_sparse_frame, cfg=cfg)))


def render_trajectory_py(scene, cam: Camera, poses: jax.Array,
                         cfg: RenderConfig, *, keep_states: bool = False
                         ) -> TrajectoryResult:
    """Legacy host-side driver: one jitted dispatch per frame.

    Kept as the golden reference for the scanned engine (it is the
    original, straightforwardly-auditable loop). Frame f is fully
    rendered when f % cfg.window == 0, warped otherwise.
    """
    full_fn, sparse_fn = _legacy_frame_fns(cfg)

    frames, records, states = [], [], []
    state = None
    ref_cam = None
    for f in range(poses.shape[0]):
        cam_f = cam.with_pose(poses[f])
        if f % cfg.window == 0 or state is None:
            out, state, rec = full_fn(scene, cam_f,
                                      frame_idx=jnp.int32(f))
            frames.append(out.rgb)
        else:
            rgb, state, rec = sparse_fn(scene, ref_cam, cam_f, state)
            frames.append(rgb)
        ref_cam = cam_f
        records.append(rec)
        if keep_states:
            states.append(state)
    stacked_states = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *states) if keep_states else None
    return TrajectoryResult(frames=jnp.stack(frames),
                            records=StackedRecords.from_list(records),
                            states=stacked_states)
