"""Image quality metrics: PSNR and SSIM (standard 11x11 Gaussian window)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def psnr(img: jax.Array, ref: jax.Array, *, max_val: float = 1.0) -> jax.Array:
    mse = jnp.mean((img - ref) ** 2)
    return 10.0 * jnp.log10(max_val * max_val / jnp.maximum(mse, 1e-12))


def _gaussian_window(size: int = 11, sigma: float = 1.5) -> jax.Array:
    x = jnp.arange(size, dtype=jnp.float32) - (size - 1) / 2.0
    g = jnp.exp(-(x ** 2) / (2 * sigma ** 2))
    g = g / jnp.sum(g)
    return g


def _filter2d(img: jax.Array, win: jax.Array) -> jax.Array:
    """Separable valid-mode filtering of (H, W, C) with 1D window."""
    def conv1d(x, axis):
        x = jnp.moveaxis(x, axis, -1)
        pad = 0
        out = jax.vmap(lambda row: jnp.convolve(row, win, mode="valid"))(
            x.reshape(-1, x.shape[-1]))
        out = out.reshape(*x.shape[:-1], out.shape[-1])
        return jnp.moveaxis(out, -1, axis)

    out = img
    out = conv1d(out, 0)
    out = conv1d(out, 1)
    return out


def ssim(img: jax.Array, ref: jax.Array, *, max_val: float = 1.0) -> jax.Array:
    """Mean SSIM over an (H, W, 3) image pair (Wang et al. 2004 constants)."""
    c1 = (0.01 * max_val) ** 2
    c2 = (0.03 * max_val) ** 2
    win = _gaussian_window()

    # Channels are independent: move to leading axis and vmap.
    def per_channel(x, y):
        mu_x = _filter2d(x[..., None], win)[..., 0]
        mu_y = _filter2d(y[..., None], win)[..., 0]
        mu_xx = mu_x * mu_x
        mu_yy = mu_y * mu_y
        mu_xy = mu_x * mu_y
        sig_xx = _filter2d((x * x)[..., None], win)[..., 0] - mu_xx
        sig_yy = _filter2d((y * y)[..., None], win)[..., 0] - mu_yy
        sig_xy = _filter2d((x * y)[..., None], win)[..., 0] - mu_xy
        num = (2 * mu_xy + c1) * (2 * sig_xy + c2)
        den = (mu_xx + mu_yy + c1) * (sig_xx + sig_yy + c2)
        return jnp.mean(num / den)

    vals = jax.vmap(per_channel, in_axes=(-1, -1))(img, ref)
    return jnp.mean(vals)
