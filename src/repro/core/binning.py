"""Per-tile binning + depth sort (the paper's "Sorting" stage, TPU-native).

GPU 3DGS builds dynamically-sized per-tile pair lists with a global radix
sort over (tileID | depth) keys. That shape-dynamic pattern does not map to
TPU/XLA; instead we keep a dense intersection mask and extract, per tile,
the indices of the K nearest intersecting Gaussians in depth order (fixed
capacity K, overflow counted — see DESIGN.md §3).

Everything here is row-agnostic: the plan-driven renderer passes an
(N, R) plan-masked mask and gets (R, K) compacted bins for the TilePlan's
R slots (DESIGN.md §2); the dense reference path passes (N, T) and gets
(T, K). The gather indices + validity mask are what the Pallas
rasterizer consumes.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.projection import ProjectedGaussians


class TileBins(NamedTuple):
    indices: jax.Array   # (T, K) int32 gaussian ids, depth-ascending
    valid: jax.Array     # (T, K) bool
    count: jax.Array     # (T,)  int32 number of valid entries (<= K)
    overflow: jax.Array  # (T,)  int32 pairs dropped because count > K
    capacity: int

    @property
    def total_pairs(self) -> jax.Array:
        return jnp.sum(self.count)


class TileGaussians(NamedTuple):
    """Per-tile gathered splat data — direct input to the rasterizer."""

    mean2d: jax.Array   # (T, K, 2)
    conic: jax.Array    # (T, K, 3)
    rgb: jax.Array      # (T, K, 3)
    opacity: jax.Array  # (T, K)
    depth: jax.Array    # (T, K)
    valid: jax.Array    # (T, K) bool


def build_tile_bins(mask_nt: jax.Array, depth: jax.Array, capacity: int,
                    *, depth_limit: jax.Array | None = None) -> TileBins:
    """Select and depth-sort up to ``capacity`` Gaussians per tile/slot.

    mask_nt: (N, T) intersection mask — or (N, R) for a plan's compacted
    slots; depth: (N,) camera z.
    depth_limit: optional (T,)/(R,) per-tile early-stop depth from DPES —
    pairs beyond it are culled *before* sorting (paper Sec. IV-B: "Any
    Gaussians beyond this depth will not be involved in sorting").
    """
    n = mask_nt.shape[0]
    mask_tn = mask_nt.T                                       # (T, N)
    if depth_limit is not None:
        mask_tn = mask_tn & (depth[None, :] <= depth_limit[:, None])
    key = jnp.where(mask_tn, depth[None, :], jnp.inf)         # (T, N)
    # Stable ascending sort: invalid entries (inf) sink to the end.
    neg_topk, idx = jax.lax.top_k(-key, min(capacity, n))     # (T, K)
    sorted_depth = -neg_topk
    valid = jnp.isfinite(sorted_depth)
    count_full = jnp.sum(mask_tn, axis=1).astype(jnp.int32)   # (T,)
    count = jnp.minimum(count_full, capacity).astype(jnp.int32)
    overflow = jnp.maximum(count_full - capacity, 0).astype(jnp.int32)
    return TileBins(indices=idx.astype(jnp.int32), valid=valid, count=count,
                    overflow=overflow, capacity=capacity)


def gather_tiles(proj: ProjectedGaussians, bins: TileBins) -> TileGaussians:
    """Gather per-tile splat attributes. (T, K, ...)."""
    idx = bins.indices
    return TileGaussians(
        mean2d=proj.mean2d[idx], conic=proj.conic[idx], rgb=proj.rgb[idx],
        opacity=jnp.where(bins.valid, proj.opacity[idx], 0.0),
        # NOTE: invalid entries get depth 0 (not inf): they blend with w=0 and
        # 0 * inf would poison the depth accumulators with NaN.
        depth=jnp.where(bins.valid, proj.depth[idx], 0.0),
        valid=bins.valid)
