"""Gaussian-tile intersection tests (paper Sec. IV-C).

Four tests over the same (N gaussians x T tiles) domain, all returning a
boolean mask (N, T). Every test reads only ``origins``/``centers`` from
the grid argument, so they equally accept a compacted ``TileSlots`` view
(``take_tiles``) and then return a plan-shaped (N, R) mask — this is how
the plan-driven renderer (core/pipeline.py) makes sparse-frame intersect
cost scale with the re-render slot count R instead of T:

- ``aabb_mask``    : original 3DGS — circumscribed square of the 3-sigma
                     circle (coarse baseline, many false positives).
- ``obb_mask``     : GSCore-style oriented-bounding-box separating-axis test
                     (comparison point in Fig. 9).
- ``tait_mask``    : the paper's two-stage test — opacity-aware tight bbox
                     (stage 1, eqs. 4+6) then the single minor-axis distance
                     rejection (stage 2, eq. 7).
- ``exact_mask``   : analytic ellipse-vs-rectangle oracle (FlashGS-class
                     accuracy) used for validation and Fig. 9's lower bound.

Note on eq. (7): as printed, ``|l| cos(theta) + r > R_minor`` would reject
tiles whose centers lie within one tile-circumradius *inside* the ellipse
boundary, i.e. it can drop true intersections. We implement the safe
(conservative) form ``|l| cos(theta) - r > R_minor`` => reject, which keeps
TAIT a superset of the exact test; the property test
``tests/test_intersect.py::test_tait_between_exact_and_aabb`` enforces it.
This sign choice is recorded in DESIGN.md §3.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.camera import TILE, Camera
from repro.core.projection import ProjectedGaussians

# Circumcircle radius of a 16x16 tile (r in eq. 7).
TILE_CIRCUMRADIUS = float(TILE) * (2.0 ** 0.5) / 2.0


class TileGrid(NamedTuple):
    tiles_x: int
    tiles_y: int
    centers: jax.Array  # (T, 2) pixel coords of tile centers
    origins: jax.Array  # (T, 2) pixel coords of tile upper-left corners

    @property
    def num_tiles(self) -> int:
        return self.tiles_x * self.tiles_y


class TileSlots(NamedTuple):
    """Compacted view of R plan slots — duck-typed grid for the tests."""

    centers: jax.Array  # (R, 2) pixel coords of slot tile centers
    origins: jax.Array  # (R, 2) pixel coords of slot tile upper-left


def take_tiles(grid: TileGrid, tile_ids: jax.Array) -> TileSlots:
    """Gather the grid rows of a plan's tile ids: (T,)-world -> (R,)-world."""
    return TileSlots(centers=grid.centers[tile_ids],
                     origins=grid.origins[tile_ids])


def make_tile_grid(cam: Camera) -> TileGrid:
    tx = jnp.arange(cam.tiles_x, dtype=jnp.float32) * TILE
    ty = jnp.arange(cam.tiles_y, dtype=jnp.float32) * TILE
    ox, oy = jnp.meshgrid(tx, ty, indexing="xy")
    origins = jnp.stack([ox.ravel(), oy.ravel()], axis=-1)       # (T, 2)
    centers = origins + TILE / 2.0
    return TileGrid(cam.tiles_x, cam.tiles_y, centers, origins)


def _rect_overlap(mean2d, half_wh, grid: TileGrid) -> jax.Array:
    """Axis-aligned rectangle (center, half-extent) vs every tile. (N, T)."""
    lo = mean2d - half_wh                                       # (N, 2)
    hi = mean2d + half_wh
    t_lo = grid.origins                                         # (T, 2)
    t_hi = grid.origins + TILE
    ov_x = (lo[:, None, 0] < t_hi[None, :, 0]) & (hi[:, None, 0] > t_lo[None, :, 0])
    ov_y = (lo[:, None, 1] < t_hi[None, :, 1]) & (hi[:, None, 1] > t_lo[None, :, 1])
    return ov_x & ov_y


def aabb_mask(proj: ProjectedGaussians, grid: TileGrid) -> jax.Array:
    """Original 3DGS test: square of half-extent 3*sqrt(lambda1). (N, T)."""
    r = proj.radius3[:, None]
    half = jnp.concatenate([r, r], axis=-1)
    return _rect_overlap(proj.mean2d, half, grid) & proj.valid[:, None]


def tait_stage1_mask(proj: ProjectedGaussians, grid: TileGrid) -> jax.Array:
    """Stage 1: opacity-aware tight bbox of the effective ellipse. (N, T)."""
    return _rect_overlap(proj.mean2d, proj.tight_half_wh, grid) & proj.valid[:, None]


def tait_mask(proj: ProjectedGaussians, grid: TileGrid) -> jax.Array:
    """Full two-stage TAIT test (stage 1 bbox, then eq. 7 rejection)."""
    stage1 = tait_stage1_mask(proj, grid)
    # Stage 2: component of (tile center - ellipse center) along the minor
    # axis. Reject when it exceeds R_minor + tile circumradius (safe form).
    d = grid.centers[None, :, :] - proj.mean2d[:, None, :]      # (N, T, 2)
    along_minor = jnp.abs(jnp.einsum("ntc,nc->nt", d, proj.minor_axis))
    keep = along_minor - TILE_CIRCUMRADIUS <= proj.r_minor[:, None]
    return stage1 & keep


def obb_mask(proj: ProjectedGaussians, grid: TileGrid) -> jax.Array:
    """GSCore-style OBB vs tile square, separating-axis theorem. (N, T).

    OBB axes = ellipse eigenvectors with half-extents (R_major, R_minor);
    tile axes = x/y with half-extent TILE/2. Four candidate separating axes.
    """
    minor = proj.minor_axis                                     # (N, 2)
    major = jnp.stack([-minor[:, 1], minor[:, 0]], axis=-1)     # perpendicular
    d = grid.centers[None, :, :] - proj.mean2d[:, None, :]      # (N, T, 2)
    half_t = TILE / 2.0
    rmaj = proj.r_major[:, None]
    rmin = proj.r_minor[:, None]

    # Axis 1: image x. OBB projects to |maj_x|*rmaj + |min_x|*rmin.
    obb_px = jnp.abs(major[:, 0:1]) * rmaj + jnp.abs(minor[:, 0:1]) * rmin
    sep_x = jnp.abs(d[..., 0]) > (obb_px + half_t)
    # Axis 2: image y.
    obb_py = jnp.abs(major[:, 1:2]) * rmaj + jnp.abs(minor[:, 1:2]) * rmin
    sep_y = jnp.abs(d[..., 1]) > (obb_py + half_t)
    # Axis 3: ellipse major axis. Tile projects to half_t*(|ax|+|ay|).
    tile_pm = half_t * (jnp.abs(major[:, 0:1]) + jnp.abs(major[:, 1:2]))
    sep_maj = jnp.abs(jnp.einsum("ntc,nc->nt", d, major)) > (rmaj + tile_pm)
    # Axis 4: ellipse minor axis.
    tile_pn = half_t * (jnp.abs(minor[:, 0:1]) + jnp.abs(minor[:, 1:2]))
    sep_min = jnp.abs(jnp.einsum("ntc,nc->nt", d, minor)) > (rmin + tile_pn)

    separated = sep_x | sep_y | sep_maj | sep_min
    return (~separated) & proj.valid[:, None]


def exact_mask(proj: ProjectedGaussians, grid: TileGrid) -> jax.Array:
    """Analytic oracle: does the effective ellipse touch the tile rectangle?

    The effective ellipse is {p : (p-mu)^T Sigma^-1 (p-mu) <= rho2} with
    rho2 = 2 ln(o / tau) (matching eq. 4's radii). A rectangle intersects
    iff the minimum of the quadratic over the rectangle is <= rho2. The
    minimum is attained at the center (if inside the rect) or on one of the
    four edges; each edge minimum has a closed form (clamped 1D quadratic).
    """
    mu = proj.mean2d                                           # (N, 2)
    con_a, con_b, con_c = proj.conic[:, 0], proj.conic[:, 1], proj.conic[:, 2]
    opac = proj.opacity
    rho2 = 2.0 * jnp.log(jnp.maximum(opac / (1.0 / 255.0), 1.0 + 1e-6))

    lo = grid.origins                                           # (T, 2)
    hi = grid.origins + TILE

    def quad(dx, dy):
        return con_a[:, None] * dx * dx + 2.0 * con_b[:, None] * dx * dy \
            + con_c[:, None] * dy * dy

    # Center inside rectangle -> minimum is 0.
    inside = ((mu[:, None, 0] >= lo[None, :, 0]) & (mu[:, None, 0] <= hi[None, :, 0])
              & (mu[:, None, 1] >= lo[None, :, 1]) & (mu[:, None, 1] <= hi[None, :, 1]))

    # Edge minima. For a vertical edge x = x0, y in [y0, y1]:
    # minimize A dx^2 + 2B dx dy + C dy^2 over dy => dy* = -B dx / C, clamp.
    def vedge(x0):
        dx = x0[None, :] - mu[:, 0:1]                           # (N, T)
        dy_star = -con_b[:, None] * dx / jnp.maximum(con_c[:, None], 1e-12)
        dy = jnp.clip(dy_star, lo[None, :, 1] - mu[:, 1:2],
                      hi[None, :, 1] - mu[:, 1:2])
        return quad(dx, dy)

    def hedge(y0):
        dy = y0[None, :] - mu[:, 1:2]
        dx_star = -con_b[:, None] * dy / jnp.maximum(con_a[:, None], 1e-12)
        dx = jnp.clip(dx_star, lo[None, :, 0] - mu[:, 0:1],
                      hi[None, :, 0] - mu[:, 0:1])
        return quad(dx, dy)

    qmin = jnp.minimum(jnp.minimum(vedge(lo[:, 0]), vedge(hi[:, 0])),
                       jnp.minimum(hedge(lo[:, 1]), hedge(hi[:, 1])))
    qmin = jnp.where(inside, 0.0, qmin)
    return (qmin <= rho2[:, None]) & proj.valid[:, None]


def pair_count(mask: jax.Array) -> jax.Array:
    """Total Gaussian-tile pairs a test admits (Fig. 9 metric)."""
    return jnp.sum(mask.astype(jnp.int32))


def per_tile_count(mask: jax.Array) -> jax.Array:
    """(T,) pairs per tile — the tile workload before DPES."""
    return jnp.sum(mask.astype(jnp.int32), axis=0)


def intersect(proj: ProjectedGaussians, grid: TileGrid, method: str) -> jax.Array:
    fns = {"aabb": aabb_mask, "obb": obb_mask, "tait": tait_mask,
           "tait_stage1": tait_stage1_mask, "exact": exact_mask}
    return fns[method](proj, grid)
