"""Rasterization orchestrator: tiles in, full-frame images out.

``render_plan_slots`` is the plan-driven production path: it rasterizes
only a TilePlan's R compacted slots and scatters the tile images back
into the full frame (untouched tiles read as empty: rgb 0, T = 1), so
raster cost scales with R. ``render_from_bins`` keeps the dense (T,)
layout for oracle comparisons and stage-isolation benchmarks.

Also hosts the brute-force whole-image oracle used by integration tests:
it blends *every* valid Gaussian into *every* pixel in global depth order —
no tiling, no intersection test, no capacity — so any tiling/binning/raster
bug shows up as a pixel diff.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import binning
from repro.core.camera import TILE, Camera
from repro.core.intersect import TileGrid
from repro.core.projection import ProjectedGaussians
from repro.kernels import ops as kops


class RenderOutput(NamedTuple):
    rgb: jax.Array          # (H, W, 3)
    transmittance: jax.Array  # (H, W) final T per pixel
    exp_depth: jax.Array    # (H, W) opacity-weighted depth (Sec. IV-A)
    trunc_depth: jax.Array  # (H, W) early-stop depth (Sec. IV-B)
    processed_pairs: jax.Array  # (T,) pairs traversed per tile (raster work)
    # Temporal-prior contribution statistics (DESIGN.md §12). Per (bin
    # row, lane): the sum of blend weights over the tile's pixels, in bin
    # lane order (0 past the count / for inactive slots). Rows follow the
    # call's bin layout — dense (T, K) from ``render_from_bins``, plan
    # slots (R, K) from ``render_plan_slots`` (so sparse frames stay
    # R-shaped; ``pipeline._plan_record`` scatters to (T, K) only when
    # the record asks for it). Per Gaussian: the same mass scatter-added
    # over the bin indices — what key frames store as the culling prior.
    # The oracle leaves both zeroed.
    lane_contrib: jax.Array     # (rows, K) float32
    gauss_contrib: jax.Array    # (N,) float32


def untile(tiles: jax.Array, tiles_x: int, tiles_y: int) -> jax.Array:
    """(T, TILE, TILE, C?) -> (H, W, C?)."""
    extra = tiles.shape[3:]
    x = tiles.reshape(tiles_y, tiles_x, TILE, TILE, *extra)
    x = jnp.swapaxes(x, 1, 2)
    return x.reshape(tiles_y * TILE, tiles_x * TILE, *extra)


def tile_view(img: jax.Array, tiles_x: int, tiles_y: int) -> jax.Array:
    """(H, W, C?) -> (T, TILE, TILE, C?). Inverse of ``untile``."""
    extra = img.shape[2:]
    x = img.reshape(tiles_y, TILE, tiles_x, TILE, *extra)
    x = jnp.swapaxes(x, 1, 2)
    return x.reshape(tiles_y * tiles_x, TILE, TILE, *extra)


def _gauss_contrib(proj: ProjectedGaussians, bins: binning.TileBins,
                   lane_contrib: jax.Array) -> jax.Array:
    """(rows, K) per-lane contributions -> (N,) per-Gaussian totals.

    Invalid lanes contribute exactly 0 (their opacity is zeroed by
    ``gather_tiles``), so the scatter-add needs no validity mask.
    """
    n = proj.depth.shape[0]
    return jnp.zeros((n,), jnp.float32).at[bins.indices].add(lane_contrib)


def render_from_bins(proj: ProjectedGaussians, bins: binning.TileBins,
                     grid: TileGrid, *, impl: str = "jnp_chunked",
                     chunk: int = 64) -> RenderOutput:
    tg = binning.gather_tiles(proj, bins)
    rgb_t, trans_t, d_t, td_t, proc, contrib = kops.raster_tiles(
        tg.mean2d, tg.conic, tg.rgb, tg.opacity, tg.depth,
        grid.origins, bins.count, impl=impl, chunk=chunk)
    return RenderOutput(
        rgb=untile(rgb_t, grid.tiles_x, grid.tiles_y),
        transmittance=untile(trans_t, grid.tiles_x, grid.tiles_y),
        exp_depth=untile(d_t, grid.tiles_x, grid.tiles_y),
        trunc_depth=untile(td_t, grid.tiles_x, grid.tiles_y),
        processed_pairs=proc,
        lane_contrib=contrib,
        gauss_contrib=_gauss_contrib(proj, bins, contrib))


def render_plan_slots(proj: ProjectedGaussians, bins: binning.TileBins,
                      slot_origins: jax.Array, tile_ids: jax.Array,
                      grid: TileGrid, *, impl: str = "jnp_chunked",
                      chunk: int = 64,
                      slot_active: jax.Array | None = None) -> RenderOutput:
    """Rasterize a TilePlan's R slots, scatter back to the (T,) frame.

    ``bins`` is the (R, K) compacted binning; ``slot_origins``/``tile_ids``
    come from the plan (``intersect.take_tiles`` / ``TilePlan.tile_ids``)
    and ``slot_active`` is the plan's slot mask — on the fused Pallas path
    it drives the per-slot early exit (DESIGN.md §9). Tiles outside the
    plan never reach the rasterizer and read back as empty (rgb/depth 0,
    transmittance 1, 0 processed pairs) — this is where TWSR's wall-clock
    win comes from on real hardware.
    """
    tg = binning.gather_tiles(proj, bins)
    rgb_s, trans_s, d_s, td_s, proc, contrib_s = kops.raster_tiles(
        tg.mean2d, tg.conic, tg.rgb, tg.opacity, tg.depth,
        slot_origins, bins.count, impl=impl, chunk=chunk,
        slot_active=slot_active)
    t = grid.num_tiles
    rgb_all = jnp.zeros((t, TILE, TILE, 3)).at[tile_ids].set(rgb_s)
    trans_all = jnp.full((t, TILE, TILE), 1.0).at[tile_ids].set(trans_s)
    d_all = jnp.zeros((t, TILE, TILE)).at[tile_ids].set(d_s)
    td_all = jnp.zeros((t, TILE, TILE)).at[tile_ids].set(td_s)
    proc_all = jnp.zeros((t,), jnp.int32).at[tile_ids].set(proc)
    return RenderOutput(
        rgb=untile(rgb_all, grid.tiles_x, grid.tiles_y),
        transmittance=untile(trans_all, grid.tiles_x, grid.tiles_y),
        exp_depth=untile(d_all, grid.tiles_x, grid.tiles_y),
        trunc_depth=untile(td_all, grid.tiles_x, grid.tiles_y),
        processed_pairs=proc_all,
        lane_contrib=contrib_s,
        gauss_contrib=_gauss_contrib(proj, bins, contrib_s))


def render_oracle(proj: ProjectedGaussians, cam: Camera) -> RenderOutput:
    """Brute-force per-pixel blend over ALL Gaussians, depth-sorted globally.

    O(H*W*N) — for small test scenes only.
    """
    n = proj.depth.shape[0]
    key = jnp.where(proj.valid, proj.depth, jnp.inf)
    order = jnp.argsort(key)
    mean2d = proj.mean2d[order]
    conic = proj.conic[order]
    rgb = proj.rgb[order]
    opac = jnp.where(proj.valid[order], proj.opacity[order], 0.0)
    depth = proj.depth[order]

    u = jnp.arange(cam.width, dtype=jnp.float32) + 0.5
    v = jnp.arange(cam.height, dtype=jnp.float32) + 0.5
    px, py = jnp.meshgrid(u, v, indexing="xy")
    px, py = px.ravel(), py.ravel()
    p = cam.width * cam.height

    from repro.kernels.ref import ALPHA_MAX, ALPHA_MIN, T_EPS

    def body(carry, g):
        color, trans, done, dacc, wacc, tdepth = carry
        m, con, c, o, d = g
        dx = px - m[0]
        dy = py - m[1]
        power = -0.5 * (con[0] * dx * dx + con[2] * dy * dy) - con[1] * dx * dy
        alpha = jnp.minimum(o * jnp.exp(power), ALPHA_MAX)
        alpha = jnp.where(alpha >= ALPHA_MIN, alpha, 0.0)
        test_t = trans * (1.0 - alpha)
        trigger = (alpha > 0.0) & (test_t < T_EPS)   # sticky done (CUDA)
        blend = (alpha > 0.0) & ~done & ~trigger
        w = jnp.where(blend, alpha * trans, 0.0)
        color = color + w[:, None] * c[None, :]
        dacc = dacc + w * d
        wacc = wacc + w
        tdepth = jnp.where(blend, jnp.maximum(tdepth, d), tdepth)
        trans = jnp.where(blend, test_t, trans)
        done = done | trigger
        return (color, trans, done, dacc, wacc, tdepth), None

    init = (jnp.zeros((p, 3)), jnp.ones((p,)), jnp.zeros((p,), bool),
            jnp.zeros((p,)), jnp.zeros((p,)), jnp.zeros((p,)))
    (color, trans, done, dacc, wacc, tdepth), _ = jax.lax.scan(
        body, init, (mean2d, conic, rgb, opac, depth))
    h, w = cam.height, cam.width
    n_tiles = (h // TILE) * (w // TILE)
    return RenderOutput(
        rgb=color.reshape(h, w, 3), transmittance=trans.reshape(h, w),
        exp_depth=(dacc / jnp.maximum(wacc, 1e-8)).reshape(h, w),
        trunc_depth=tdepth.reshape(h, w),
        processed_pairs=jnp.zeros((n_tiles,), jnp.int32),
        lane_contrib=jnp.zeros((n_tiles, 1), jnp.float32),
        gauss_contrib=jnp.zeros((n,), jnp.float32))
