"""Temporal contribution culling for TWSR sparse frames (DESIGN.md §12).

At each key frame the rasterizer reports, per Gaussian, the total blend
mass it contributed to the frame (``RenderOutput.gauss_contrib`` — the
sum of ``alpha * T_before`` over every pixel it was blended into). The
streaming loop stores ``inf`` for Gaussians that were never *considered*
at the key frame (not binned into any tile), so newly-visible Gaussians
are always kept, and carries the result across frames as
``FrameState.contrib``.

On sparse frames this module maps the prior through the viewpoint warp:
culling applies only in plan slots whose tile has usable reprojection
sources (``WarpResult.valid_per_tile > 0`` — elsewhere the warp saw
nothing, so the prior says nothing about that view) and removes
intersection pairs whose Gaussian contributed less than the threshold
*before* binning, so sort and raster work shrink with the prior. Slots
whose pairs are all culled are demoted to interpolation
(``slot_active = False``), which feeds straight back into
``plan.rerender_demand`` and the serving layer's capacity suggestions.

``cull_threshold = 0.0`` (the default) keeps the pipeline bit-exact with
the uncull path: the pass is structurally skipped via a Python-level
branch on the static ``RenderConfig``, not merely an all-keep mask.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def warp_gate(valid_per_tile: jax.Array) -> jax.Array:
    """(T,) warp source-pixel counts -> (T,) bool cull gate.

    True where the viewpoint transform found at least one usable
    reprojection source in the tile — only there does the key-frame
    contribution prior describe what the new view needs.
    """
    return valid_per_tile > 0


def cull_pairs(mask: jax.Array, slot_active: jax.Array, tile_ids: jax.Array,
               prior: jax.Array, gate: jax.Array, threshold: float
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Apply the contribution prior to the (N, R) intersection mask.

    mask (N, R) bool   pair mask after the plan's slot_active masking
    slot_active (R,)   the plan's active-slot flags
    tile_ids (R,)      the plan's tile ids (to gather the gate per slot)
    prior (N,)         key-frame per-Gaussian contribution; ``inf`` means
                       "not considered at the key frame" and always keeps
    gate (T,)          bool, True where the warp has usable priors
    threshold          keep iff ``prior >= threshold``

    Returns ``(mask, slot_active, culled_pairs)``: the culled pair mask,
    the slot flags with fully-culled slots demoted (they degrade to
    warp/interpolation exactly like plan-capacity overflow), and the
    scalar count of pairs removed.
    """
    keep = prior >= threshold                      # inf prior -> True
    gated = gate[tile_ids] & slot_active           # (R,) slots we may cull
    new_mask = mask & (keep[:, None] | ~gated[None, :])
    culled = (jnp.sum(mask.astype(jnp.int32))
              - jnp.sum(new_mask.astype(jnp.int32)))
    pre = jnp.sum(mask.astype(jnp.int32), axis=0)
    post = jnp.sum(new_mask.astype(jnp.int32), axis=0)
    demote = (pre > 0) & (post == 0)
    return new_mask, slot_active & ~demote, culled
