"""DPES — Depth Prediction for Early Stopping (paper Sec. IV-B).

The reference frame's truncated depth map (depth at which blending
early-stopped, produced by the rasterizer) is reprojected by
``warp.viewpoint_transform``; this module turns the per-tile early-stop
depths into (a) pre-sort Gaussian culling and (b) per-tile *workload
predictions* for the LDU (Sec. V-B).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class TileWorkload(NamedTuple):
    raw: jax.Array        # (T,) pairs per tile before DPES
    predicted: jax.Array  # (T,) pairs per tile after DPES depth culling
    culled: jax.Array     # (T,) pairs removed by DPES


def apply_depth_limit(mask_nt: jax.Array, depth: jax.Array,
                      dpes_depth: jax.Array, *,
                      margin: float = 1.0) -> jax.Array:
    """Cull (gaussian, tile) pairs beyond the tile's early-stop depth.

    mask_nt: (N, T); depth: (N,); dpes_depth: (T,) with inf = no prior.
    ``margin`` scales the limit (1.0 = faithful to the paper).
    """
    limit = dpes_depth * margin
    return mask_nt & (depth[:, None] <= limit[None, :])


def predict_workload(mask_nt: jax.Array, depth: jax.Array,
                     dpes_depth: jax.Array, *,
                     margin: float = 1.0) -> TileWorkload:
    """Per-tile effective workload estimate (pairs surviving DPES)."""
    raw = jnp.sum(mask_nt.astype(jnp.int32), axis=0)
    culled_mask = apply_depth_limit(mask_nt, depth, dpes_depth, margin=margin)
    predicted = jnp.sum(culled_mask.astype(jnp.int32), axis=0)
    return TileWorkload(raw=raw, predicted=predicted, culled=raw - predicted)
