"""repro: LS-Gaussian (streaming 3DGS) + multi-pod JAX training substrate."""

__version__ = "0.1.0"
