"""Serving steps: prefill + decode (the shapes the dry-run lowers).

``prefill`` runs the full forward, builds the KV/SSM caches and pads them
to ``max_seq`` so the decode loop is shape-static. ``decode`` emits one
token per call; greedy sampling built in for the serving example.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.models.layers import KVCache, MLACache


def _pad_cache_seq(cache: M.DecodeCache, max_seq: int) -> M.DecodeCache:
    """Grow kv caches built at prompt length to the serving window."""
    def pad_axis(a, axis):
        if a.shape[axis] >= max_seq:
            return a
        widths = [(0, 0)] * a.ndim
        widths[axis] = (0, max_seq - a.shape[axis])
        return jnp.pad(a, widths)

    kv = cache.kv
    if isinstance(kv, KVCache):
        kv = KVCache(k=pad_axis(kv.k, 3), v=pad_axis(kv.v, 3))
    elif isinstance(kv, MLACache):
        kv = MLACache(c_kv=pad_axis(kv.c_kv, 2),
                      k_rope=pad_axis(kv.k_rope, 2))
    shared = cache.shared_kv
    if isinstance(shared, KVCache):
        shared = KVCache(k=pad_axis(shared.k, 3), v=pad_axis(shared.v, 3))
    return cache._replace(kv=kv, shared_kv=shared)


def prefill(params, batch: Dict[str, jax.Array], cfg: ArchConfig,
            max_seq: Optional[int] = None
            ) -> Tuple[jax.Array, M.DecodeCache]:
    """Returns (logits (B,S,V), cache ready for decode)."""
    logits, _, cache = M.forward(params, batch, cfg, build_cache=True)
    if cfg.family == "hybrid":
        # hybrid prefill rebuilds per-invocation caches via decode layout
        raise NotImplementedError(
            "hybrid prefill->decode chaining uses serve loop in "
            "examples/serve_lm.py (cache built by forward covers kv only)")
    if max_seq is not None:
        cache = _pad_cache_seq(cache, max_seq)
    return logits, cache


def decode(params, tokens: jax.Array, cache: M.DecodeCache,
           cfg: ArchConfig) -> Tuple[jax.Array, M.DecodeCache]:
    """One decode step: tokens (B,1) -> (logits (B,1,V), updated cache)."""
    return M.decode_step(params, tokens, cache, cfg)


def greedy_generate(params, prompt: jax.Array, cfg: ArchConfig, *,
                    max_new: int, max_seq: int):
    """Reference generation loop (batched greedy)."""
    b, s = prompt.shape
    if cfg.family in ("ssm", "hybrid", "encdec", "vlm"):
        raise NotImplementedError("example loop targets decoder-only LMs")
    logits, cache = prefill(params, {"tokens": prompt}, cfg, max_seq=max_seq)
    next_tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
    cache = cache._replace(index=jnp.int32(s))
    toks = [next_tok]

    step_fn = jax.jit(lambda p, t, c: M.decode_step(p, t, c, cfg))
    for _ in range(max_new - 1):
        logits, cache = step_fn(params, next_tok, cache)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        toks.append(next_tok)
    return jnp.concatenate(toks, axis=1)
