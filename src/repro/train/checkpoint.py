"""Fault-tolerant checkpointing: atomic, content-addressed, reshardable.

Layout per step:  <dir>/step_<n>/arrays.npz + manifest.json
  - write goes to a tmp dir then os.rename (atomic on POSIX): a crash
    mid-write never corrupts the latest checkpoint;
  - manifest carries the flattened key paths + step + user metadata, so
    restore validates structure instead of trusting pickles;
  - ``restore(..., shardings=...)`` device_puts every leaf with the TARGET
    sharding: loading onto a different mesh (elastic re-mesh) is just a
    different shardings pytree — nothing about the mesh is persisted.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def save(ckpt_dir: str, step: int, tree, *, metadata: Optional[dict] = None,
         keep: int = 3) -> str:
    """Atomically persist a pytree; prunes old steps beyond ``keep``."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = {k: np.asarray(jax.device_get(v)) for k, v in _flatten(tree).items()}
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {"step": step, "keys": sorted(flat.keys()),
                    "metadata": metadata or {}}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _prune(ckpt_dir, keep)
    return final


def _prune(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.startswith(".")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, template, *, step: Optional[int] = None,
            shardings=None):
    """Load into the structure of ``template``.

    shardings: optional pytree (congruent with template) of Sharding
    objects — leaves are device_put with them (elastic re-mesh path).
    Returns (tree, step, metadata).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))

    flat_template = _flatten(template)
    missing = set(flat_template) - set(manifest["keys"])
    extra = set(manifest["keys"]) - set(flat_template)
    if missing or extra:
        raise ValueError(f"checkpoint/template mismatch: missing={sorted(missing)[:5]} "
                         f"extra={sorted(extra)[:5]}")
    flat_shard = _flatten(shardings) if shardings is not None else {}

    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for path, leaf in leaves_with_path:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = data[key]
        if arr.shape != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        val = jax.numpy.asarray(arr, dtype=leaf.dtype)
        if key in flat_shard and flat_shard[key] is not None:
            val = jax.device_put(val, flat_shard[key])
        out.append(val)
    return treedef.unflatten(out), step, manifest["metadata"]
