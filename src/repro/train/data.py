"""Synthetic token data pipeline.

Deterministic, seekable (state = step index), so checkpoint/restart resumes
the exact stream — the property the fault-tolerance test exercises.

The stream is a noisy affine recurrence t_{i+1} = (a * t_i + c) mod V with
p_noise random replacements: learnable structure (loss drops quickly) but
non-degenerate.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch_size: int = 8
    seq_len: int = 128
    vocab_size: int = 512
    seed: int = 0
    p_noise: float = 0.1
    mult: int = 31
    add: int = 7


def batch_at(cfg: DataConfig, step: int) -> Dict[str, jax.Array]:
    """Batch for a given global step (pure function of (cfg, step))."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    k0, k1, k2 = jax.random.split(key, 3)
    start = jax.random.randint(k0, (cfg.batch_size, 1), 0, cfg.vocab_size)
    # affine recurrence, vectorized via closed form on cumulative powers
    def step_fn(t, _):
        nxt = (t * cfg.mult + cfg.add) % cfg.vocab_size
        return nxt, nxt

    _, seq = jax.lax.scan(step_fn, start[:, 0], None, length=cfg.seq_len)
    tokens = jnp.concatenate([start, seq.T], axis=1)  # (B, S+1)
    noise = jax.random.bernoulli(k1, cfg.p_noise, tokens.shape)
    rand = jax.random.randint(k2, tokens.shape, 0, cfg.vocab_size)
    tokens = jnp.where(noise, rand, tokens).astype(jnp.int32)
    return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


def stream(cfg: DataConfig, start_step: int = 0) -> Iterator[Dict[str, jax.Array]]:
    step = start_step
    while True:
        yield batch_at(cfg, step)
        step += 1
