"""Train / eval step factories.

``make_train_step(cfg, opt_cfg, mesh)`` returns a jit-ready pure function
``(TrainState, batch) -> (TrainState, metrics)``. When a mesh is supplied,
logits/loss get explicit sharding constraints (vocab over "model", batch
over the data axes) so the 200k-vocab CE never materializes unsharded.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.train.optimizer import (OptimizerConfig, OptState, adamw_update,
                                   init_opt_state)

MOE_AUX_WEIGHT = 0.01


class TrainState(NamedTuple):
    params: dict
    opt: OptState


def _batch_axes(mesh: Optional[Mesh]):
    if mesh is None:
        return None
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token CE in fp32. logits (B,S,V), labels (B,S) int32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    true_logit = jnp.take_along_axis(logits, labels[..., None],
                                     axis=-1)[..., 0]
    nll = lse - true_logit
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def make_loss_fn(cfg: ArchConfig, mesh: Optional[Mesh] = None):
    baxes = _batch_axes(mesh)

    def loss_fn(params, batch):
        logits, aux, _ = M.forward(params, batch, cfg)
        if mesh is not None:
            vocab_axis = "model" \
                if cfg.vocab_size % mesh.shape["model"] == 0 else None
            logits = jax.lax.with_sharding_constraint(
                logits, NamedSharding(mesh, P(baxes, None, vocab_axis)))
        loss = cross_entropy(logits, batch["labels"], batch.get("mask"))
        total = loss + MOE_AUX_WEIGHT * aux
        return total, {"loss": loss, "aux_loss": aux}

    return loss_fn


def make_train_step(cfg: ArchConfig, opt_cfg: OptimizerConfig,
                    mesh: Optional[Mesh] = None):
    loss_fn = make_loss_fn(cfg, mesh)

    def train_step(state: TrainState, batch) -> Tuple[TrainState, dict]:
        (_, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params, batch)
        new_params, new_opt, opt_metrics = adamw_update(
            grads, state.opt, state.params, opt_cfg)
        metrics = dict(metrics, **opt_metrics, step=new_opt.step)
        return TrainState(params=new_params, opt=new_opt), metrics

    return train_step


def init_train_state(key, cfg: ArchConfig) -> TrainState:
    params = M.init_params(key, cfg)
    return TrainState(params=params, opt=init_opt_state(params))
