"""Sharded AdamW + LR schedule (functional, no optax dependency).

Optimizer state is a pytree congruent with params, so GSPMD shards it
exactly like the parameters (ZeRO-style for FSDP-sharded weights). Moments
are fp32 regardless of param dtype.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


def lr_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    progress = jnp.clip((step - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) \
        * 0.5 * (1 + jnp.cos(jnp.pi * progress))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init_opt_state(params) -> OptState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.int32(0), mu=zeros,
                    nu=jax.tree_util.tree_map(jnp.copy, zeros))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree_util.tree_leaves(tree)))


def adamw_update(grads, opt: OptState, params,
                 cfg: OptimizerConfig) -> Tuple[dict, OptState, dict]:
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = lr_schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt.mu)
    flat_v = treedef.flatten_up_to(opt.nu)
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(step=step, mu=new_m, nu=new_v), metrics
