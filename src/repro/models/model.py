"""Model factory: init / forward / prefill / decode for every family.

Layer stacks run under ``jax.lax.scan`` over stacked parameters (HLO stays
small for 95-layer configs; remat policy selectable). Caches mirror the
stacking so decode threads them through the same scan.

Families:
  dense   : [attn -> mlp] x L                       (yi, deepseek, starcoder2,
                                                     minicpm3 w/ MLA)
  moe     : [attn -> moe] x L                       (moonshot, llama4)
  ssm     : [mamba2] x L                            (mamba2-780m)
  hybrid  : mamba2 x L + shared attn block every k  (zamba2)
  encdec  : encoder [attn -> mlp] + decoder w/ cross-attn  (whisper; stub
            frontend supplies frame embeddings)
  vlm     : vision-prefix embeddings + dense decoder       (internvl2; stub
            frontend supplies patch embeddings)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.sharding_hooks import constrain


def _dtype(cfg: ArchConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ArchConfig, dtype) -> Dict[str, Any]:
    """One decoder block's params (unstacked)."""
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {}
    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        p["ln1"] = jnp.ones((cfg.d_model,), dtype)
        p["attn"] = (L.init_mla(ks[0], cfg, dtype) if cfg.attention == "mla"
                     else L.init_gqa(ks[0], cfg, dtype))
        p["ln2"] = jnp.ones((cfg.d_model,), dtype)
        if cfg.family == "moe":
            p["moe"] = L.init_moe(ks[1], cfg, dtype)
        else:
            p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff,
                                  cfg.mlp_type, dtype)
        if cfg.family == "encdec":
            p["ln_x"] = jnp.ones((cfg.d_model,), dtype)
            p["xattn"] = L.init_gqa(ks[2], cfg, dtype)
    elif cfg.family in ("ssm", "hybrid"):
        p["ln1"] = jnp.ones((cfg.d_model,), dtype)
        p["ssm"] = L.init_mamba2(ks[0], cfg, dtype)
    return p


def _init_shared_attn(key, cfg: ArchConfig, dtype) -> Dict[str, Any]:
    ks = jax.random.split(key, 2)
    d_ff = cfg.shared_attn_d_ff or cfg.d_ff
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "attn": L.init_gqa(ks[0], cfg, dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "mlp": L.init_mlp(ks[1], cfg.d_model, d_ff, "swiglu", dtype),
    }


def _stack(blocks):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks)


def init_params(key, cfg: ArchConfig) -> Dict[str, Any]:
    dtype = _dtype(cfg)
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": L._init(ks[0], (cfg.vocab_size, cfg.d_model), scale=0.02,
                         dtype=dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L._init(ks[1], (cfg.d_model, cfg.vocab_size),
                                    dtype=dtype)

    layer_keys = jax.random.split(ks[2], cfg.num_layers)
    blocks = [_init_block(k, cfg, dtype) for k in layer_keys]
    # hybrid always stacks: its group/tail slicing assumes stacked leaves.
    stack = cfg.scan_layers or cfg.family == "hybrid"
    params["layers"] = _stack(blocks) if stack else blocks

    if cfg.family == "hybrid":
        params["shared_attn"] = _init_shared_attn(ks[3], cfg, dtype)
    if cfg.family == "encdec":
        enc_keys = jax.random.split(ks[4], cfg.encoder_layers)
        enc_cfg = cfg  # same dims for encoder blocks
        enc_blocks = []
        for ek in enc_keys:
            eks = jax.random.split(ek, 2)
            enc_blocks.append({
                "ln1": jnp.ones((cfg.d_model,), dtype),
                "attn": L.init_gqa(eks[0], enc_cfg, dtype),
                "ln2": jnp.ones((cfg.d_model,), dtype),
                "mlp": L.init_mlp(eks[1], cfg.d_model, cfg.d_ff,
                                  cfg.mlp_type, dtype),
            })
        params["encoder"] = _stack(enc_blocks) if cfg.scan_layers else enc_blocks
        params["enc_final_norm"] = jnp.ones((cfg.d_model,), dtype)
    if cfg.family == "vlm":
        params["vision_proj"] = L._init(ks[5], (cfg.d_model, cfg.d_model),
                                        dtype=dtype)
    return params


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

class DecodeCache(NamedTuple):
    """Union cache across families; unused fields are None."""

    kv: Optional[Any]          # stacked L.KVCache (L, ...) or MLACache
    ssm: Optional[L.SSMState]  # stacked (L, ...)
    shared_kv: Optional[Any]   # (n_invocations, ...) for zamba shared block
    enc_out: Optional[jax.Array]  # (B, enc_seq, D) for whisper cross-attn
    cross_kv: Optional[Any]    # stacked (L, B, G, enc_seq, K) precomputed
    index: jax.Array           # () int32 — next write position


def init_cache(cfg: ArchConfig, batch: int, max_seq: int,
               enc_out: Optional[jax.Array] = None,
               with_cross_kv: bool = True) -> DecodeCache:
    dtype = _dtype(cfg)
    n_l = cfg.num_layers
    kv = ssm = shared = cross = None
    hd = cfg.resolved_head_dim
    if cfg.family == "encdec" and with_cross_kv:
        cross = L.KVCache(
            k=jnp.zeros((n_l, batch, cfg.num_kv_heads, cfg.encoder_seq, hd),
                        dtype),
            v=jnp.zeros((n_l, batch, cfg.num_kv_heads, cfg.encoder_seq, hd),
                        dtype))
    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        if cfg.attention == "mla":
            kv = L.MLACache(
                c_kv=jnp.zeros((n_l, batch, max_seq, cfg.kv_lora_rank), dtype),
                k_rope=jnp.zeros((n_l, batch, max_seq, cfg.rope_head_dim),
                                 dtype))
        else:
            kv = L.KVCache(
                k=jnp.zeros((n_l, batch, cfg.num_kv_heads, max_seq, hd), dtype),
                v=jnp.zeros((n_l, batch, cfg.num_kv_heads, max_seq, hd), dtype))
    if cfg.family in ("ssm", "hybrid"):
        conv_dim = cfg.d_inner + 2 * cfg.ssm_state
        ssm = L.SSMState(
            h=jnp.zeros((n_l, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                         cfg.ssm_state), jnp.float32),
            conv=jnp.zeros((n_l, batch, conv_dim, cfg.ssm_conv_width - 1),
                           dtype))
    if cfg.family == "hybrid":
        n_inv = cfg.num_layers // cfg.shared_attn_every
        shared = L.KVCache(
            k=jnp.zeros((n_inv, batch, cfg.num_kv_heads, max_seq, hd), dtype),
            v=jnp.zeros((n_inv, batch, cfg.num_kv_heads, max_seq, hd), dtype))
    return DecodeCache(kv=kv, ssm=ssm, shared_kv=shared, enc_out=enc_out,
                       cross_kv=cross, index=jnp.int32(0))


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------

def _apply_block(p, x, positions, cfg: ArchConfig, *, cache=None,
                 cache_index=None, return_cache=False, enc_out=None,
                 ssm_state=None, cross_kv=None):
    """One decoder block. Returns (x, new_kv, new_ssm, aux_loss[, cross])."""
    # Sequence-parallel residual stream (hook set by the step factories):
    # remat saves this carry per layer, so sharding it over "model" is
    # what keeps the 95-layer configs inside HBM (DESIGN.md §5).
    x = constrain(x, "residual")
    aux = jnp.float32(0.0)
    new_kv = new_ssm = None
    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        if cfg.attention == "mla":
            y, new_kv = L.mla_attention(p["attn"], h, positions, cfg,
                                        cache=cache, cache_index=cache_index,
                                        return_cache=return_cache)
        else:
            y, new_kv = L.gqa_attention(p["attn"], h, positions, cfg,
                                        cache=cache, cache_index=cache_index,
                                        return_cache=return_cache)
        x = x + y
        if cfg.family == "encdec":
            h = L.rmsnorm(p["ln_x"], x, cfg.norm_eps)
            if cross_kv is not None:
                y, _ = L.gqa_attention(p["xattn"], h, positions, cfg,
                                       causal=False, static_kv=cross_kv)
            else:
                y, _ = L.gqa_attention(p["xattn"], h, positions, cfg,
                                       causal=False, kv_x=enc_out)
            x = x + y
        h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        if cfg.family == "moe":
            y, aux = L.moe_block(p["moe"], h, cfg)
        else:
            y = L.mlp(p["mlp"], h, cfg.mlp_type)
        x = x + y
    else:  # ssm / hybrid mamba block
        h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        y, new_ssm = L.mamba2_mix(p["ssm"], h, cfg, state=ssm_state,
                                  return_state=return_cache or
                                  ssm_state is not None)
        x = x + y
    return x, new_kv, new_ssm, aux


def _apply_shared_attn(p, x, positions, cfg, *, cache=None, cache_index=None,
                       return_cache=False):
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    y, new_kv = L.gqa_attention(p["attn"], h, positions, cfg, cache=cache,
                                cache_index=cache_index,
                                return_cache=return_cache)
    x = x + y
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    x = x + L.mlp(p["mlp"], h, "swiglu")
    return x, new_kv


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _maybe_remat(fn, cfg: ArchConfig):
    if cfg.remat == "none":
        return fn
    policy = None
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    return jax.checkpoint(fn, policy=policy)


def _run_layers(params, x, positions, cfg: ArchConfig, *, build_cache=False,
                enc_out=None):
    """Scan the decoder stack. Returns (x, stacked kv caches, stacked ssm
    states, total aux loss)."""
    if cfg.family == "hybrid":
        return _run_layers_hybrid(params, x, positions, cfg,
                                  build_cache=build_cache)

    def body(carry, lp):
        h, aux_acc = carry
        h, kv, ssm, aux = _apply_block(lp, h, positions, cfg,
                                       return_cache=build_cache,
                                       enc_out=enc_out)
        out = {}
        if kv is not None:
            out["kv"] = kv
        if ssm is not None:
            out["ssm"] = ssm
        if build_cache and cfg.family == "encdec":
            # precompute this layer's cross-attention K/V once (§Perf:
            # whisper decode otherwise re-projects 1500 frames per step)
            ck = jnp.einsum("btd,dgk->bgtk", enc_out, lp["xattn"]["wk"])
            cv = jnp.einsum("btd,dgk->bgtk", enc_out, lp["xattn"]["wv"])
            out["cross"] = L.KVCache(ck, cv)
        return (h, aux_acc + aux), out

    body = _maybe_remat(body, cfg)
    if cfg.scan_layers:
        (x, aux), outs = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                      params["layers"])
    else:
        aux = jnp.float32(0.0)
        outs_list = []
        for lp in params["layers"]:
            (x, aux), o = body((x, aux), lp)
            outs_list.append(o)
        outs = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs_list) \
            if outs_list and outs_list[0] else {}
    kv = outs.get("kv") if isinstance(outs, dict) else None
    ssm = outs.get("ssm") if isinstance(outs, dict) else None
    cross = outs.get("cross") if isinstance(outs, dict) else None
    return x, kv, ssm, aux, cross


def _run_layers_hybrid(params, x, positions, cfg: ArchConfig, *,
                       build_cache=False):
    """Zamba2: groups of ``shared_attn_every`` mamba layers, each followed
    by the SHARED attention block (same params every invocation)."""
    k = cfg.shared_attn_every
    n_groups = cfg.num_layers // k
    tail = cfg.num_layers - n_groups * k
    shared_p = params["shared_attn"]

    def split_group(tree, start, size):
        return jax.tree_util.tree_map(lambda a: a[start:start + size], tree)

    grouped = split_group(params["layers"], 0, n_groups * k)
    grouped = jax.tree_util.tree_map(
        lambda a: a.reshape(n_groups, k, *a.shape[1:]), grouped)
    tail_p = split_group(params["layers"], n_groups * k, tail)

    def inner(h, lp):
        h, _, ssm, _ = _apply_block(lp, h, positions, cfg,
                                    return_cache=build_cache)
        return h, {"ssm": ssm} if ssm is not None else {}

    def group_body(carry, gp):
        h, aux = carry
        if cfg.scan_layers:
            h, inner_outs = jax.lax.scan(inner, h, gp)
        else:
            inner_list = []
            for i in range(k):
                h, o = inner(h, jax.tree_util.tree_map(lambda a: a[i], gp))
                inner_list.append(o)
            inner_outs = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *inner_list) \
                if inner_list and inner_list[0] else {}
        h, kv = _apply_shared_attn(shared_p, h, positions, cfg,
                                   return_cache=build_cache)
        outs = dict(inner_outs)
        if kv is not None:
            outs["kv"] = kv
        return (h, aux), outs

    group_body = _maybe_remat(group_body, cfg)
    if cfg.scan_layers:
        (x, aux), outs = jax.lax.scan(group_body, (x, jnp.float32(0.0)),
                                      grouped)
    else:
        outs_list = []
        carry = (x, jnp.float32(0.0))
        for gi in range(n_groups):
            gp = jax.tree_util.tree_map(lambda a: a[gi], grouped)
            carry, o = group_body(carry, gp)
            outs_list.append(o)
        x, aux = carry
        outs = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                      *outs_list) \
            if outs_list and outs_list[0] else {}

    tail_ssm = None
    if tail:  # leftover layers after the last full group, applied unrolled
        tail_states = []
        h = x
        for i in range(tail):
            lp = jax.tree_util.tree_map(lambda a: a[i], tail_p)
            h, _, ssm, _ = _apply_block(lp, h, positions, cfg,
                                        return_cache=build_cache)
            tail_states.append(ssm)
        x = h
        if build_cache and tail_states[0] is not None:
            tail_ssm = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                              *tail_states)

    ssm_states = outs.get("ssm")
    kv = outs.get("kv")
    if build_cache and ssm_states is not None:
        flat = jax.tree_util.tree_map(
            lambda a: a.reshape(n_groups * k, *a.shape[2:]), ssm_states)
        if tail_ssm is not None:
            flat = jax.tree_util.tree_map(
                lambda a, b: jnp.concatenate([a, b], 0), flat, tail_ssm)
        ssm_states = flat
    return x, kv, ssm_states, aux, None


def _encode(params, frames, cfg: ArchConfig):
    """Whisper encoder over precomputed frame embeddings (stub frontend)."""
    x = frames
    pos = jnp.broadcast_to(jnp.arange(x.shape[1])[None], x.shape[:2])

    def body(h, lp):
        y, _ = L.gqa_attention(lp["attn"], L.rmsnorm(lp["ln1"], h,
                                                     cfg.norm_eps),
                               pos, cfg, causal=False)
        h = h + y
        h = h + L.mlp(lp["mlp"], L.rmsnorm(lp["ln2"], h, cfg.norm_eps),
                      cfg.mlp_type)
        return h, None

    body = _maybe_remat(body, cfg)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(lambda h, lp: body(h, lp), x, params["encoder"])
    else:
        for lp in params["encoder"]:
            x, _ = body(x, lp)
    return L.rmsnorm(params["enc_final_norm"], x, cfg.norm_eps)


def forward(params, batch: Dict[str, jax.Array], cfg: ArchConfig,
            *, build_cache: bool = False):
    """Full forward over a token batch.

    batch: {"tokens": (B, S) int32, optional "frames": (B, enc_seq, D),
    optional "vision": (B, V, D)}. Returns (logits, aux_loss, cache).
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"][tokens].astype(_dtype(cfg))
    enc_out = None
    offset = 0
    if cfg.family == "encdec":
        enc_out = _encode(params, batch["frames"].astype(_dtype(cfg)), cfg)
    if cfg.family == "vlm":
        vis = jnp.einsum("bvd,de->bve", batch["vision"].astype(_dtype(cfg)),
                         params["vision_proj"])
        x = jnp.concatenate([vis, x], axis=1)
        offset = vis.shape[1]
    positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None],
                                 (b, x.shape[1]))
    x, kv, ssm, aux, cross = _run_layers(params, x, positions, cfg,
                                         build_cache=build_cache,
                                         enc_out=enc_out)
    x = x[:, offset:]
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    cache = None
    if build_cache:
        cache = DecodeCache(kv=kv, ssm=ssm, shared_kv=None, enc_out=enc_out,
                            cross_kv=cross, index=jnp.int32(s + offset))
    return logits, aux, cache


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def decode_step(params, tokens: jax.Array, cache: DecodeCache,
                cfg: ArchConfig):
    """One-token decode: tokens (B, 1) -> (logits (B, 1, V), new cache)."""
    b = tokens.shape[0]
    x = params["embed"][tokens].astype(_dtype(cfg))
    idx = cache.index
    positions = jnp.full((b, 1), idx, jnp.int32)

    if cfg.family == "hybrid":
        x, new_kv, new_ssm = _decode_hybrid(params, x, positions, cache, cfg)
        new_cache = cache._replace(ssm=new_ssm, shared_kv=new_kv,
                                   index=idx + 1)
    else:
        def body(h, xs):
            lp, layer_cache = xs
            kv_c = layer_cache.get("kv")
            ssm_c = layer_cache.get("ssm")
            h, kv, ssm, _ = _apply_block(
                lp, h, positions, cfg, cache=kv_c, cache_index=idx,
                enc_out=cache.enc_out, ssm_state=ssm_c,
                cross_kv=layer_cache.get("cross"))
            out = {}
            if kv is not None:
                out["kv"] = kv
            if ssm is not None:
                out["ssm"] = ssm
            return h, out

        layer_caches = {}
        if cache.kv is not None:
            layer_caches["kv"] = cache.kv
        if cache.ssm is not None:
            layer_caches["ssm"] = cache.ssm
        if cache.cross_kv is not None:
            layer_caches["cross"] = cache.cross_kv
        if cfg.scan_layers:
            x, outs = jax.lax.scan(body, x, (params["layers"], layer_caches))
        else:
            outs_list = []
            for i, lp in enumerate(params["layers"]):
                lc = jax.tree_util.tree_map(lambda a: a[i], layer_caches)
                x, o = body(x, (lp, lc))
                outs_list.append(o)
            outs = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                          *outs_list)
        new_cache = cache._replace(kv=outs.get("kv"), ssm=outs.get("ssm"),
                                   index=idx + 1)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    return logits, new_cache


def _decode_hybrid(params, x, positions, cache: DecodeCache, cfg):
    k = cfg.shared_attn_every
    n_groups = cfg.num_layers // k
    tail = cfg.num_layers - n_groups * k
    idx = cache.index
    shared_p = params["shared_attn"]

    grouped = jax.tree_util.tree_map(
        lambda a: a[: n_groups * k].reshape(n_groups, k, *a.shape[1:]),
        params["layers"])
    g_ssm = jax.tree_util.tree_map(
        lambda a: a[: n_groups * k].reshape(n_groups, k, *a.shape[1:]),
        cache.ssm)

    def inner(h, xs):
        lp, st = xs
        h, _, ssm, _ = _apply_block(lp, h, positions, cfg, ssm_state=st)
        return h, ssm

    def group_body(h, xs):
        gp, gstate, kv_c = xs
        if cfg.scan_layers:
            h, new_states = jax.lax.scan(inner, h, (gp, gstate))
        else:
            states = []
            for i in range(k):
                h, st = inner(h, jax.tree_util.tree_map(
                    lambda a: a[i], (gp, gstate)))
                states.append(st)
            new_states = jax.tree_util.tree_map(
                lambda *xs_: jnp.stack(xs_), *states)
        h, kv = _apply_shared_attn(shared_p, h, positions, cfg, cache=kv_c,
                                   cache_index=idx)
        return h, {"ssm": new_states, "kv": kv}

    if cfg.scan_layers:
        x, outs = jax.lax.scan(group_body, x,
                               (grouped, g_ssm, cache.shared_kv))
    else:
        outs_list = []
        for gi in range(n_groups):
            xs = jax.tree_util.tree_map(lambda a: a[gi],
                                        (grouped, g_ssm, cache.shared_kv))
            x, o = group_body(x, xs)
            outs_list.append(o)
        outs = jax.tree_util.tree_map(lambda *xs_: jnp.stack(xs_),
                                      *outs_list)
    new_ssm = jax.tree_util.tree_map(
        lambda a: a.reshape(n_groups * k, *a.shape[2:]), outs["ssm"])
    if tail:
        tail_p = jax.tree_util.tree_map(lambda a: a[n_groups * k:],
                                        params["layers"])
        tail_s = jax.tree_util.tree_map(lambda a: a[n_groups * k:], cache.ssm)
        states = []
        for i in range(tail):
            lp = jax.tree_util.tree_map(lambda a: a[i], tail_p)
            st = jax.tree_util.tree_map(lambda a: a[i], tail_s)
            x, _, ssm, _ = _apply_block(lp, x, positions, cfg, ssm_state=st)
            states.append(ssm)
        tail_new = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)
        new_ssm = jax.tree_util.tree_map(
            lambda a, b: jnp.concatenate([a, b], 0), new_ssm, tail_new)
    return x, outs["kv"], new_ssm
