"""Functional model components for the architecture zoo.

Conventions:
  - params are nested dicts of jnp arrays; init_* builds them, apply-style
    functions consume them. No framework, donate/shard-friendly.
  - activations (B, S, D); caches are explicit NamedTuples so serve_step
    can thread them through jax.lax.scan over layers.
  - dims named in einsums: b batch, s/t seq, d model, h heads, g kv-heads,
    k head_dim, f ffn, e experts, c capacity/latent, n ssm-state, p
    ssm-head-dim, q chunk.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.sharding_hooks import constrain, get_flag


def _init(key, shape, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else (1.0 / (shape[0] ** 0.5))
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms / rope
# ---------------------------------------------------------------------------

def rmsnorm(scale: jax.Array, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * scale).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, K) with K even; positions: (B, S) int32."""
    k = x.shape[-1]
    freqs = rope_freqs(k, theta)                           # (K/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, K/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jax.Array  # (B, G, S, K)
    v: jax.Array  # (B, G, S, K)


def init_gqa(key, cfg: ArchConfig, dtype) -> dict:
    d, h, g = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    k = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": _init(ks[0], (d, h, k), dtype=dtype),
        "wk": _init(ks[1], (d, g, k), dtype=dtype),
        "wv": _init(ks[2], (d, g, k), dtype=dtype),
        "wo": _init(ks[3], (h, k, d), scale=1.0 / (h * k) ** 0.5, dtype=dtype),
    }


def _sdpa(q, k, v, mask):
    """q (B,S,G,Hq,K), k/v (B,G,T,K), mask (B,1,1,S,T) or None.

    Materialized softmax (train path): the "attn_scores_gqa" hook shards
    the (B,G,H,S,T) score tensor's query-seq axis over "model"
    (Megatron-SP style) so the S x T block never replicates — and because
    remat replays constraints, the backward recompute is sharded too.
    """
    scale = 1.0 / (q.shape[-1] ** 0.5)
    scores = jnp.einsum("bsghk,bgtk->bghst", q, k) * scale
    scores = constrain(scores.astype(jnp.float32), "attn_scores_gqa")
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    probs = constrain(probs, "attn_scores_gqa")
    return jnp.einsum("bghst,bgtk->bsghk", probs, v)


# Sequence length above which the train/prefill path switches from the
# materialized softmax to the chunked online-softmax (flash) formulation.
FLASH_THRESHOLD = 1024
FLASH_Q_CHUNK = 512
FLASH_KV_CHUNK = 1024


def flash_attention(q, k, v, *, causal: bool, scale: float,
                    q_chunk: int = FLASH_Q_CHUNK,
                    kv_chunk: int = FLASH_KV_CHUNK,
                    causal_skip: bool = False):
    """Online-softmax (flash) attention in GQA layout, O(qc*kc) score memory.

    q (B,S,G,Hq,K), k (B,G,T,K), v (B,G,T,Kv) -> out (B,S,G,Hq,Kv).

    Baseline computes the full S x T rectangle with masking. With
    ``causal_skip`` the inner scan only visits kv chunks that intersect
    the causal triangle of the current q chunk (beyond-paper §Perf
    iteration: halves attention-score FLOPs at long context).
    """
    b, s, g, hq, d = q.shape
    t = k.shape[2]
    dv = v.shape[-1]
    nq = s // q_chunk if (s % q_chunk == 0 and s >= q_chunk) else 1
    qc = s // nq
    nk = t // kv_chunk if (t % kv_chunk == 0 and t >= kv_chunk) else 1
    kc = t // nk

    qb = jnp.moveaxis(q.reshape(b, nq, qc, g, hq, d), 1, 0)
    kb = jnp.moveaxis(k.reshape(b, g, nk, kc, d), 2, 0)
    vb = jnp.moveaxis(v.reshape(b, g, nk, kc, dv), 2, 0)

    def q_block(_, iq_qi):
        iq, qi = iq_qi                                     # qi (B,qc,G,Hq,K)
        q_pos = iq * qc + jnp.arange(qc)

        def kv_block(state, jk_kv):
            jk, kj, vj = jk_kv                             # kj (B,G,kc,K)
            acc, m, l = state
            scores = jnp.einsum("bqghk,bgtk->bghqt", qi, kj) * scale
            scores = scores.astype(jnp.float32)
            k_pos = jk * kc + jnp.arange(kc)
            if causal:
                mask = q_pos[:, None] >= k_pos[None, :]
                scores = jnp.where(mask[None, None, None], scores, -1e30)
            m_new = jnp.maximum(m, scores.max(-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(scores - m_new[..., None])
            l_new = l * alpha + p.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bghqt,bgtv->bghqv", p, vj.astype(jnp.float32))
            return (acc_new, m_new, l_new), None

        init = (jnp.zeros((b, g, hq, qc, dv), jnp.float32),
                jnp.full((b, g, hq, qc), -jnp.inf, jnp.float32),
                jnp.zeros((b, g, hq, qc), jnp.float32))
        if causal_skip and causal and s == t:
            # only kv chunks 0..iq intersect the triangle; bound the scan
            # with a while_loop over a traced limit.
            def cond(c):
                return c[0] <= iq

            def body(c):
                j, state = c
                state, _ = kv_block(state, (j, kb[j], vb[j]))
                return j + 1, state

            _, (acc, m, l) = jax.lax.while_loop(
                cond, body, (jnp.int32(0), init))
        else:
            (acc, m, l), _ = jax.lax.scan(
                kv_block, init, (jnp.arange(nk), kb, vb))
        out = (acc / jnp.maximum(l[..., None], 1e-30)).astype(q.dtype)
        return None, jnp.moveaxis(out, 3, 1)               # (B,qc,G,Hq,Kv)

    _, outs = jax.lax.scan(q_block, None, (jnp.arange(nq), qb))
    return jnp.moveaxis(outs, 0, 1).reshape(b, s, g, hq, dv)


def gqa_attention(params: dict, x: jax.Array, positions: jax.Array,
                  cfg: ArchConfig, *, causal: bool = True,
                  cache: Optional[KVCache] = None,
                  cache_index: Optional[jax.Array] = None,
                  return_cache: bool = False,
                  kv_x: Optional[jax.Array] = None,
                  static_kv: Optional[KVCache] = None):
    """GQA attention; cross-attention when kv_x is given.

    Modes:
      - cache is None: full self-attention over x (train/prefill); when
        return_cache, also emits the packed cache.
      - cache given + cache_index: decode — one (or few) new tokens, cache
        updated at cache_index.
      - static_kv: PRECOMPUTED cross-attention K/V (whisper decode) — no
        projection, no cache update (§Perf: avoids re-encoding the 1500
        encoder frames every decode step).
    """
    b, s, d = x.shape
    h, g = cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if static_kv is not None:
        q = q.reshape(b, s, g, h // g, hd)
        out = _sdpa(q, static_kv.k, static_kv.v, None)
        out = out.reshape(b, s, h, hd)
        return jnp.einsum("bshk,hkd->bsd", out, params["wo"]), None
    src = kv_x if kv_x is not None else x
    k = jnp.einsum("bsd,dgk->bsgk", src, params["wk"])
    v = jnp.einsum("bsd,dgk->bsgk", src, params["wv"])
    if kv_x is None:  # rope only for self-attention
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    k = jnp.swapaxes(k, 1, 2)                              # (B, G, S, K)
    v = jnp.swapaxes(v, 1, 2)
    q = q.reshape(b, s, g, h // g, hd)

    if cache is not None:
        if s == 1:
            k_all = cache.k.at[:, :, cache_index, :].set(k[:, :, 0, :])
            v_all = cache.v.at[:, :, cache_index, :].set(v[:, :, 0, :])
        else:
            k_all = jax.lax.dynamic_update_slice(
                cache.k, k, (0, 0, cache_index, 0))
            v_all = jax.lax.dynamic_update_slice(
                cache.v, v, (0, 0, cache_index, 0))
        t = cache.k.shape[2]
        # valid positions: <= current index
        tpos = jnp.arange(t)[None, None, None, None, :]
        mask = tpos <= cache_index
        out = _sdpa(q, k_all, v_all, mask)
        new_cache = KVCache(k_all, v_all)
    else:
        t = src.shape[1]
        is_causal = causal and kv_x is None
        impl = get_flag("attn_impl", "auto")
        use_flash = impl == "flash" or (
            impl == "auto" and s >= FLASH_THRESHOLD and t >= FLASH_THRESHOLD)
        if use_flash:
            out = flash_attention(q, k, v, causal=is_causal,
                                  scale=1.0 / (hd ** 0.5),
                                  causal_skip=bool(get_flag("causal_skip",
                                                            False)))
        else:
            if is_causal:
                mask = (jnp.arange(t)[None, :] <= jnp.arange(s)[:, None])
                mask = mask[None, None, None, :, :]
            else:
                mask = None
            out = _sdpa(q, k, v, mask)
        new_cache = KVCache(k, v) if return_cache else None

    out = out.reshape(b, s, h, hd)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return (y, new_cache) if (return_cache or cache is not None) else (y, None)


# ---------------------------------------------------------------------------
# MLA attention (MiniCPM3 / DeepSeek-V2)
# ---------------------------------------------------------------------------

class MLACache(NamedTuple):
    c_kv: jax.Array    # (B, S, C) compressed latent
    k_rope: jax.Array  # (B, S, R) shared rotary key


def init_mla(key, cfg: ArchConfig, dtype) -> dict:
    d, h = cfg.d_model, cfg.num_heads
    qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
    nd, rd, vd = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    return {
        "w_dq": _init(ks[0], (d, qr), dtype=dtype),
        "q_norm": jnp.ones((qr,), dtype),
        "w_uq": _init(ks[1], (qr, h, nd + rd), dtype=dtype),
        "w_dkv": _init(ks[2], (d, kr), dtype=dtype),
        "kv_norm": jnp.ones((kr,), dtype),
        "w_kr": _init(ks[3], (d, rd), dtype=dtype),
        "w_uk": _init(ks[4], (kr, h, nd), dtype=dtype),
        "w_uv": _init(ks[5], (kr, h, vd), dtype=dtype),
        "wo": _init(ks[6], (h, vd, d), scale=1.0 / (h * vd) ** 0.5,
                    dtype=dtype),
    }


def mla_attention(params: dict, x: jax.Array, positions: jax.Array,
                  cfg: ArchConfig, *, cache: Optional[MLACache] = None,
                  cache_index: Optional[jax.Array] = None,
                  return_cache: bool = False):
    b, s, d = x.shape
    h = cfg.num_heads
    nd, rd = cfg.nope_head_dim, cfg.rope_head_dim
    scale = 1.0 / ((nd + rd) ** 0.5)

    cq = rmsnorm(params["q_norm"], jnp.einsum("bsd,dc->bsc", x, params["w_dq"]),
                 cfg.norm_eps)
    q = jnp.einsum("bsc,chk->bshk", cq, params["w_uq"])
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv = rmsnorm(params["kv_norm"],
                  jnp.einsum("bsd,dc->bsc", x, params["w_dkv"]), cfg.norm_eps)
    kr_new = jnp.einsum("bsd,dr->bsr", x, params["w_kr"])[:, :, None, :]
    kr_new = apply_rope(kr_new, positions, cfg.rope_theta)[:, :, 0, :]

    if cache is not None:
        if s == 1:
            c_all = cache.c_kv.at[:, cache_index, :].set(ckv[:, 0, :])
            r_all = cache.k_rope.at[:, cache_index, :].set(kr_new[:, 0, :])
        else:
            c_all = jax.lax.dynamic_update_slice(cache.c_kv, ckv,
                                                 (0, cache_index, 0))
            r_all = jax.lax.dynamic_update_slice(cache.k_rope, kr_new,
                                                 (0, cache_index, 0))
        # Absorbed decode (DeepSeek-V2 inference trick): score directly in
        # the latent space — no per-step K/V re-expansion.
        q_lat = jnp.einsum("bshn,chn->bshc", q_nope, params["w_uk"])
        scores = (jnp.einsum("bshc,btc->bhst", q_lat, c_all)
                  + jnp.einsum("bshr,btr->bhst", q_rope, r_all)) * scale
        t = c_all.shape[1]
        mask = (jnp.arange(t)[None, None, None, :] <= cache_index)
        scores = jnp.where(mask, scores.astype(jnp.float32), -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out_lat = jnp.einsum("bhst,btc->bshc", probs, c_all)
        out = jnp.einsum("bshc,chv->bshv", out_lat, params["w_uv"])
        new_cache = MLACache(c_all, r_all)
    else:
        k_nope = jnp.einsum("btc,chn->bthn", ckv, params["w_uk"])
        v = jnp.einsum("btc,chv->bthv", ckv, params["w_uv"])
        impl = get_flag("attn_impl", "auto")
        use_flash = impl == "flash" or (impl == "auto"
                                        and s >= FLASH_THRESHOLD)
        if use_flash:
            # concat nope+rope dims; per-head keys -> GQA layout g=h, hq=1
            q_cat = jnp.concatenate([q_nope, q_rope], -1)   # (B,S,H,nd+rd)
            k_cat = jnp.concatenate(
                [k_nope, jnp.broadcast_to(kr_new[:, :, None, :],
                                          (*k_nope.shape[:3], rd))], -1)
            out = flash_attention(
                q_cat.reshape(b, s, h, 1, nd + rd),
                jnp.swapaxes(k_cat, 1, 2), jnp.swapaxes(v, 1, 2),
                causal=True, scale=scale,
                causal_skip=bool(get_flag("causal_skip", False)))
            out = out.reshape(b, s, h, -1)
        else:
            scores = (jnp.einsum("bshn,bthn->bhst", q_nope, k_nope)
                      + jnp.einsum("bshr,btr->bhst", q_rope, kr_new)) * scale
            scores = constrain(scores.astype(jnp.float32), "attn_scores_mla")
            mask = (jnp.arange(s)[None, :] <= jnp.arange(s)[:, None])
            scores = jnp.where(mask[None, None], scores, -1e30)
            probs = constrain(jax.nn.softmax(scores, axis=-1),
                              "attn_scores_mla").astype(x.dtype)
            out = jnp.einsum("bhst,bthv->bshv", probs, v)
        new_cache = MLACache(ckv, kr_new) if return_cache else None

    y = jnp.einsum("bshv,hvd->bsd", out, params["wo"])
    return y, new_cache


# ---------------------------------------------------------------------------
# MLPs + MoE
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, f: int, mlp_type: str, dtype) -> dict:
    ks = jax.random.split(key, 3)
    p = {"w_in": _init(ks[0], (d, f), dtype=dtype),
         "w_out": _init(ks[1], (f, d), dtype=dtype)}
    if mlp_type == "swiglu":
        p["w_gate"] = _init(ks[2], (d, f), dtype=dtype)
    return p


def mlp(params: dict, x: jax.Array, mlp_type: str) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, params["w_in"])
    if mlp_type == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("bsf,fd->bsd", h, params["w_out"])


def init_moe(key, cfg: ArchConfig, dtype) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": _init(ks[0], (d, e), scale=0.02, dtype=jnp.float32),
        "w_in": _init(ks[1], (e, d, f), dtype=dtype),
        "w_gate": _init(ks[2], (e, d, f), dtype=dtype),
        "w_out": _init(ks[3], (e, f, d), dtype=dtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(ks[4], d, f * cfg.num_shared_experts,
                               "swiglu", dtype)
    return p


def moe_block(params: dict, x: jax.Array, cfg: ArchConfig
              ) -> Tuple[jax.Array, jax.Array]:
    """Top-k MoE with capacity cap — the LDU analogue: predicted per-expert
    load is capped at (capacity_factor x ideal), overflow tokens drop to
    the shared-expert / residual path (GShard semantics).

    Dispatch is PER-SEQUENCE ("local routing"): each batch row sorts its
    own tokens into expert bins. A flat global sort would run argsort
    along the data-sharded token axis, which forces GSPMD to replicate the
    whole dispatch (measured: 8.3 TB/step of all-reduce on
    moonshot/train_4k — EXPERIMENTS.md §Perf cell A); row-local sorting
    keeps every step shard-local and the expert combine becomes
    all-to-all-shaped.

    Decode (s == 1) takes the weight-gather path instead: FLOP-minimal,
    reads only the k routed experts' weights per token.

    Returns (output, aux_load_balance_loss).
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)        # (B, S, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # aux loss (Switch-style), normalized by k so uniform routing -> 1.0
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(expert_idx, e).sum(2), axis=(0, 1))
    aux = jnp.sum(me * ce) * e / max(k, 1)

    if s == 1:
        y = _moe_decode_dispatch(params, x, gate_vals, expert_idx, cfg)
    else:
        y = _moe_dispatch_per_row(params, x, gate_vals, expert_idx, cfg)

    if "shared" in params:
        y = y + mlp(params["shared"], x, "swiglu")
    return y, aux


def _moe_decode_dispatch(params, x, gate_vals, expert_idx, cfg):
    """Decode-regime MoE: flat dispatch over the (tiny) token batch with a
    capped expert buffer.

    The token-side arrays are B*k elements — replicating the sort is free
    — while the (E, C, d) buffer stays EXPERT-SHARDED so the per-expert
    matmuls never move weights (a per-token weight GATHER would all-gather
    the expert-sharded weights: measured +115 GiB/dev on llama4
    decode_32k). moe_decode_capacity_factor caps C (default 4x ideal);
    0 = dropless (C = tokens)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    t = b * s
    tk = t * k
    factor = cfg.moe_decode_capacity_factor or 4.0
    if cfg.moe_decode_capacity_factor == 0.0 and t <= 256:
        capacity = t                     # dropless for small serving batches
    else:
        capacity = min(t, max(k, int(round(t * k / e * factor))))

    xf = x.reshape(t, d)
    flat_e = expert_idx.reshape(tk)
    flat_g = gate_vals.reshape(tk)
    flat_tok = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(flat_e, stable=True)
    se, sg, stok = flat_e[order], flat_g[order], flat_tok[order]
    ar = jnp.arange(tk)
    new_run = jnp.concatenate([jnp.ones((1,), bool), se[1:] != se[:-1]])
    run_start = jax.lax.cummax(jnp.where(new_run, ar, 0))
    pos = ar - run_start
    keep = pos < capacity
    slot = jnp.where(keep, se * capacity + pos, e * capacity)

    buf = jnp.zeros((e * capacity + 1, d), x.dtype).at[slot].set(
        xf[stok] * keep[:, None].astype(x.dtype))
    hbuf = constrain(buf[:-1].reshape(e, capacity, d), "moe_buf_decode")
    hin = jnp.einsum("ecd,edf->ecf", hbuf, params["w_in"])
    hg = jnp.einsum("ecd,edf->ecf", hbuf, params["w_gate"])
    hout = jnp.einsum("ecf,efd->ecd", jax.nn.silu(hg) * hin,
                      params["w_out"])
    hout = constrain(hout, "moe_buf_decode")
    hflat = jnp.concatenate(
        [hout.reshape(e * capacity, d), jnp.zeros((1, d), x.dtype)], 0)
    contrib = hflat[slot] * (sg * keep)[:, None].astype(x.dtype)
    y = jnp.zeros((t, d), x.dtype).at[stok].add(contrib)
    return y.reshape(b, s, d)


def _moe_dispatch_per_row(params, x, gate_vals, expert_idx, cfg):
    """Row-local sort-based dispatch with capacity cap."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    tk = s * k
    # Dropless only at serving-scale rows: capacity = tk means every
    # expert matmul runs over a tk-deep buffer — at train rows (s=4096,
    # k=1 -> tk=4096) that is a ~E/k x compute blowup (measured: llama4
    # train compute 3.3 s -> 131 s when this threshold was 4096).
    if tk <= 512 and cfg.moe_capacity_factor >= 1.0:
        capacity = tk
    else:
        capacity = int(max(1, round(tk / e * cfg.moe_capacity_factor)))

    flat_e = expert_idx.reshape(b, tk)
    flat_g = gate_vals.reshape(b, tk)
    flat_tok = jnp.repeat(jnp.arange(s), k)[None, :]       # (1, tk)
    order = jnp.argsort(flat_e, axis=-1, stable=True)      # (B, tk)
    se = jnp.take_along_axis(flat_e, order, axis=-1)
    sg = jnp.take_along_axis(flat_g, order, axis=-1)
    stok = jnp.take_along_axis(jnp.broadcast_to(flat_tok, (b, tk)), order,
                               axis=-1)
    # position within the expert run: arange - (start index of the run)
    ar = jnp.arange(tk)[None, :]
    new_run = jnp.concatenate(
        [jnp.ones((b, 1), bool), se[:, 1:] != se[:, :-1]], axis=1)
    run_start = jax.lax.cummax(jnp.where(new_run, ar, 0), axis=1)
    pos = ar - run_start
    keep = pos < capacity
    slot = jnp.where(keep, se * capacity + pos, e * capacity)  # (B, tk)

    gathered = jnp.take_along_axis(x, stok[..., None], axis=1)  # (B,tk,d)
    gathered = gathered * keep[..., None].astype(x.dtype)
    buf = jnp.zeros((b, e * capacity + 1, d), x.dtype)
    buf = jax.vmap(lambda bu, sl, g: bu.at[sl].set(g))(buf, slot, gathered)
    hbuf = constrain(buf[:, :-1].reshape(b, e, capacity, d), "moe_buf")
    hin = jnp.einsum("becd,edf->becf", hbuf, params["w_in"])
    hg = jnp.einsum("becd,edf->becf", hbuf, params["w_gate"])
    hout = jnp.einsum("becf,efd->becd", jax.nn.silu(hg) * hin,
                      params["w_out"])
    hout = constrain(hout, "moe_buf")
    hflat = jnp.concatenate(
        [hout.reshape(b, e * capacity, d),
         jnp.zeros((b, 1, d), x.dtype)], axis=1)
    contrib = jnp.take_along_axis(hflat, slot[..., None], axis=1) \
        * (sg * keep)[..., None].astype(x.dtype)           # (B, tk, d)
    y = jnp.zeros((b, s, d), x.dtype)
    y = jax.vmap(lambda yo, tok, c: yo.at[tok].add(c))(y, stok, contrib)
    return y


# ---------------------------------------------------------------------------
# Mamba-2 (SSD) block
# ---------------------------------------------------------------------------

class SSMState(NamedTuple):
    h: jax.Array     # (B, H, P, N) recurrent state
    conv: jax.Array  # (B, conv_dim, W-1) rolling conv window


def init_mamba2(key, cfg: ArchConfig, dtype) -> dict:
    d = cfg.d_model
    d_in = cfg.d_inner
    n = cfg.ssm_state
    h = cfg.ssm_heads
    w = cfg.ssm_conv_width
    conv_dim = d_in + 2 * n
    ks = jax.random.split(key, 6)
    return {
        # projects to [z (gate), x, B, C, dt]
        "w_in": _init(ks[0], (d, 2 * d_in + 2 * n + h), dtype=dtype),
        "conv_w": _init(ks[1], (conv_dim, w), scale=0.5, dtype=dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "out_norm": jnp.ones((d_in,), dtype),
        "w_out": _init(ks[2], (d_in, d), dtype=dtype),
    }


def _segsum(a):
    """exp-able segment sums: a (..., Q) -> (..., Q, Q) lower-tri cumulative."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    # L[i, j] = exp(sum_{l=j+1..i} a_l) = exp(cs[i] - cs[j]) for i >= j.
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def mamba2_mix(params: dict, x: jax.Array, cfg: ArchConfig, *,
               state: Optional[SSMState] = None,
               return_state: bool = False):
    """Chunked SSD for train/prefill; single-step recurrence for decode."""
    b, s, d = x.shape
    d_in, n = cfg.d_inner, cfg.ssm_state
    h, p = cfg.ssm_heads, cfg.ssm_head_dim
    w = cfg.ssm_conv_width

    zxbcdt = jnp.einsum("bsd,de->bse", x, params["w_in"])
    z, xin, bmat, cmat, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xin, bmat, cmat], axis=-1)   # (B,S,conv_dim)

    if state is not None and s == 1:
        # --- decode: rolling conv + one recurrence step ------------------
        window = jnp.concatenate([state.conv, conv_in.swapaxes(1, 2)], -1)
        conv_out = jnp.einsum("bcw,cw->bc", window, params["conv_w"]) \
            + params["conv_b"]
        conv_out = jax.nn.silu(conv_out)[:, None, :]
        new_conv = window[:, :, 1:]
        xin_c, b_c, c_c = jnp.split(conv_out, [d_in, d_in + n], axis=-1)
        xh = xin_c.reshape(b, 1, h, p)[:, 0]
        dt_s = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                               + params["dt_bias"])       # (B, H)
        a = -jnp.exp(params["a_log"])                       # (H,)
        decay = jnp.exp(dt_s * a)                           # (B, H)
        dbx = jnp.einsum("bh,bn,bhp->bhpn", dt_s, b_c[:, 0].astype(jnp.float32),
                         xh.astype(jnp.float32))
        h_new = state.h * decay[:, :, None, None] + dbx
        y = jnp.einsum("bhpn,bn->bhp", h_new, c_c[:, 0].astype(jnp.float32))
        y = y + params["d_skip"][None, :, None] * xh.astype(jnp.float32)
        y = y.reshape(b, 1, d_in).astype(x.dtype)
        new_state = SSMState(h=h_new, conv=new_conv)
    else:
        # --- train/prefill: causal conv + chunked SSD --------------------
        pad = jnp.zeros((b, w - 1, conv_in.shape[-1]), conv_in.dtype) \
            if state is None else state.conv.swapaxes(1, 2)
        seq = jnp.concatenate([pad, conv_in], axis=1)       # (B, S+W-1, C)
        idx = jnp.arange(s)[:, None] + jnp.arange(w)[None, :]
        windows = seq[:, idx, :]                            # (B, S, W, C)
        conv_out = jnp.einsum("bswc,cw->bsc", windows,
                              params["conv_w"]) + params["conv_b"]
        conv_out = jax.nn.silu(conv_out)
        xin_c, b_c, c_c = jnp.split(conv_out, [d_in, d_in + n], axis=-1)

        q = min(cfg.ssm_chunk, s)
        assert s % q == 0, f"seq {s} must be divisible by ssm_chunk {q}"
        nc = s // q
        xh = xin_c.reshape(b, nc, q, h, p).astype(jnp.float32)
        bm = b_c.reshape(b, nc, q, n).astype(jnp.float32)
        cm = c_c.reshape(b, nc, q, n).astype(jnp.float32)
        dt_s = jax.nn.softplus(
            dt.reshape(b, nc, q, h).astype(jnp.float32) + params["dt_bias"])
        a = -jnp.exp(params["a_log"])                       # (H,)
        da = dt_s * a                                       # (B,NC,Q,H)
        da_h = jnp.moveaxis(da, -1, 2)                      # (B,NC,H,Q)
        xdt = xh * dt_s[..., None]                          # x pre-scaled by dt

        lmat = jnp.exp(_segsum(da_h))                       # (B,NC,H,Q,Q)
        y_diag = jnp.einsum("bcqn,bcsn,bchqs,bcshp->bcqhp",
                            cm, bm, lmat, xdt)

        cum = jnp.cumsum(da_h, axis=-1)                     # (B,NC,H,Q)
        decay_states = jnp.exp(cum[..., -1:] - cum)         # (B,NC,H,Q)
        chunk_states = jnp.einsum("bcqn,bchq,bcqhp->bchpn",
                                  bm, decay_states, xdt)
        chunk_decay = jnp.exp(cum[..., -1])                 # (B,NC,H)

        h0 = jnp.zeros((b, h, p, n), jnp.float32) if state is None \
            else state.h

        def scan_fn(carry, inp):
            st, dec = inp
            new = carry * dec[..., None, None] + st
            return new, carry  # emit state ENTERING the chunk

        hs_last, h_prevs = jax.lax.scan(
            scan_fn, h0,
            (jnp.moveaxis(chunk_states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
        h_prev = jnp.moveaxis(h_prevs, 0, 1)                # (B,NC,H,P,N)

        state_decay = jnp.exp(cum)                          # (B,NC,H,Q)
        y_off = jnp.einsum("bcqn,bchpn,bchq->bcqhp", cm, h_prev, state_decay)
        y = (y_diag + y_off).reshape(b, s, h, p)
        y = y + params["d_skip"][None, None, :, None] * xh.reshape(b, s, h, p)
        y = y.reshape(b, s, d_in).astype(x.dtype)
        new_conv = jnp.swapaxes(seq[:, -(w - 1):, :], 1, 2) \
            if return_state else None
        new_state = SSMState(h=hs_last, conv=new_conv) if return_state else None

    y = rmsnorm(params["out_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"])
    return out, new_state
