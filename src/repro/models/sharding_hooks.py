"""Activation sharding hooks.

Model code stays mesh-agnostic; step factories install NamedShardings here
(e.g. sequence-parallel residual stream). Empty by default => no-ops, so
CPU tests and single-device runs are untouched.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax

_HOOKS: Dict[str, object] = {}


def set_hooks(hooks: Optional[Dict[str, object]]) -> None:
    global _HOOKS
    _HOOKS = dict(hooks or {})


def get_hooks() -> Dict[str, object]:
    return dict(_HOOKS)


def constrain(x: jax.Array, name: str) -> jax.Array:
    s = _HOOKS.get(name)
    if s is None:
        return x
    return jax.lax.with_sharding_constraint(x, s)


def get_flag(name: str, default):
    """Non-sharding execution flags (e.g. attn_impl: sdpa|flash|auto).

    Train factories set "sdpa" (flash bwd would re-materialize S x T in
    the scan reverse); prefill factories set "flash"."""
    return _HOOKS.get(name, default)
