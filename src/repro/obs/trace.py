"""Span tracing for the serve stack: Chrome-trace/Perfetto JSON output.

The paper's thesis is that wall-clock hides *where* time goes — redundant
work and stalls are invisible in end-to-end latency. This module is the
host-side half of the observability contract (DESIGN.md §13): a
``Tracer`` records context-manager **spans** (one Chrome-trace complete
``"X"`` event per span, timed with ``time.perf_counter_ns``) onto named
**tracks** (one Chrome-trace ``tid`` per track, labelled via metadata
events), and serializes the whole buffer as a JSON object that loads
directly in ``chrome://tracing`` or https://ui.perfetto.dev.

Overhead contract:

- **Disabled** (the default): ``span()`` returns a shared no-op context
  manager — no allocation, no clock read, no lock. The serve loop keeps
  its ``with tracer.span(...)`` lines unconditionally; a disabled tracer
  makes them free.
- **Enabled**: two ``perf_counter_ns`` reads per span plus one locked
  list append at span *exit* (so a span's body never holds the lock).
  The buffer is bounded by ``keep``: the **earliest** events are
  retained (a serve run's compile spans land early — they are the ones
  CI asserts on) and later events are counted in ``dropped``.

The device-side half is :func:`annotate`: a combined
``jax.named_scope`` + ``jax.profiler.TraceAnnotation`` context manager
that engine/pipeline/kernel stages wrap their jitted bodies in.
``named_scope`` pushes the name onto the jaxpr name stack, so XLA op
names (and therefore device profiles captured with
``jax.profiler.trace``) line up with the host spans; ``TraceAnnotation``
additionally emits a TraceMe when the body runs eagerly (interpret-mode
kernels, reference paths). Both are applied *unconditionally* — they
change op metadata only, never numerics or cache keys, which is what
keeps the observer effect zero on the compiled path (pinned by
tests/test_obs.py).
"""
from __future__ import annotations

import contextlib
import json
import threading
import time
from typing import Any, Dict, List, Optional

import jax

__all__ = [
    "NULL_TRACER", "Tracer", "annotate", "validate_chrome_trace",
]


class _NullSpan:
    """Shared no-op context manager returned by a disabled tracer."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """One live span: timestamps on enter/exit, emits a complete event."""

    __slots__ = ("_tracer", "_name", "_track", "_args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, track: str,
                 args: Optional[dict]):
        self._tracer = tracer
        self._name = name
        self._track = track
        self._args = args

    def __enter__(self):
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter_ns()
        self._tracer._emit_complete(self._name, self._track, self._t0, t1,
                                    self._args)
        return False


class Tracer:
    """Bounded, thread-safe span recorder with Chrome-trace export.

    ``span(name, track=..., args=...)`` is the whole API surface the
    serve loop uses; ``instant`` marks point events (e.g. a batcher
    resize). Tracks are created on first use; every distinct ``track``
    string becomes one Chrome-trace thread row.
    """

    KEEP = 65536        # default event-buffer bound
    PID = 1             # single logical process in the trace

    def __init__(self, enabled: bool = False, keep: int = KEEP):
        if keep < 1:
            raise ValueError(f"keep must be >= 1, got {keep}")
        self.enabled = bool(enabled)
        self.keep = int(keep)
        self.dropped = 0
        self._lock = threading.Lock()
        self._events: List[dict] = []
        self._tracks: Dict[str, int] = {}
        # One clock zero per tracer: ts fields are microseconds since
        # construction, so traces from one server share an origin.
        self._t0_ns = time.perf_counter_ns()

    # -- recording ---------------------------------------------------------
    def span(self, name: str, track: str = "main",
             args: Optional[dict] = None):
        """Context manager timing its body as one complete event."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, track, args)

    def instant(self, name: str, track: str = "main",
                args: Optional[dict] = None) -> None:
        """A point event (Chrome-trace ``"i"``, thread-scoped)."""
        if not self.enabled:
            return
        now = time.perf_counter_ns()
        ev = {"name": name, "ph": "i", "s": "t",
              "ts": (now - self._t0_ns) / 1e3,
              "pid": self.PID, "tid": self._track_id(name=None, track=track)}
        if args:
            ev["args"] = dict(args)
        self._append(ev)

    def _emit_complete(self, name: str, track: str, t0_ns: int, t1_ns: int,
                       args: Optional[dict]) -> None:
        ev = {"name": name, "ph": "X",
              "ts": (t0_ns - self._t0_ns) / 1e3,
              "dur": (t1_ns - t0_ns) / 1e3,
              "pid": self.PID, "tid": self._track_id(name=None, track=track)}
        if args:
            ev["args"] = dict(args)
        self._append(ev)

    def _track_id(self, name, track: str) -> int:
        tid = self._tracks.get(track)
        if tid is None:
            with self._lock:
                tid = self._tracks.setdefault(track, len(self._tracks))
        return tid

    def _append(self, ev: dict) -> None:
        with self._lock:
            if len(self._events) < self.keep:
                self._events.append(ev)
            else:
                self.dropped += 1

    # -- export ------------------------------------------------------------
    def events(self) -> List[dict]:
        """Snapshot of the recorded events (no metadata rows)."""
        with self._lock:
            return list(self._events)

    def to_chrome(self) -> dict:
        """The JSON-object trace: metadata + recorded events.

        Track-name metadata is synthesized at export (never buffered, so
        it can't be squeezed out by the bound), and ``otherData`` carries
        the drop accounting.
        """
        with self._lock:
            events = list(self._events)
            tracks = dict(self._tracks)
            dropped = self.dropped
        meta: List[dict] = [{
            "name": "process_name", "ph": "M", "pid": self.PID, "tid": 0,
            "args": {"name": "repro-serve"}}]
        for track, tid in sorted(tracks.items(), key=lambda kv: kv[1]):
            meta.append({"name": "thread_name", "ph": "M", "pid": self.PID,
                         "tid": tid, "args": {"name": track}})
            meta.append({"name": "thread_sort_index", "ph": "M",
                         "pid": self.PID, "tid": tid,
                         "args": {"sort_index": tid}})
        return {"traceEvents": meta + events,
                "displayTimeUnit": "ms",
                "otherData": {"events": len(events), "dropped": dropped}}

    def write(self, path: str) -> int:
        """Serialize to ``path``; returns the recorded-event count."""
        trace = self.to_chrome()
        with open(path, "w") as f:
            json.dump(trace, f, indent=1)
        return int(trace["otherData"]["events"])


# The module-level disabled tracer: components that take an optional
# tracer default to this, so their span lines need no None checks.
NULL_TRACER = Tracer(enabled=False)


@contextlib.contextmanager
def annotate(name: str):
    """Name a (possibly jitted) stage for device-side profiles.

    Inside ``jit``/``scan``/``vmap`` tracing, ``jax.named_scope`` pushes
    ``name`` onto the compiled ops' name stack — an XLA profile
    (``jax.profiler.trace``) then shows the stage under the same name as
    the host spans. ``jax.profiler.TraceAnnotation`` covers the eager
    case (interpret-mode kernels, reference impls) with a TraceMe.
    Metadata only: numerics, jaxprs structure, and jit cache keys are
    unchanged, so wrapping is unconditional.
    """
    with jax.named_scope(name), jax.profiler.TraceAnnotation(name):
        yield


def validate_chrome_trace(trace: Any) -> dict:
    """Well-formedness check for an exported trace; raises ``ValueError``.

    Contract (what tests and ``scripts/trace_summary.py --check``
    enforce): a dict with a ``traceEvents`` list; every event has
    ``name``/``ph``/``ts``/``pid``/``tid``; complete (``"X"``) events
    have ``dur >= 0``; and per track the X events observe stack
    discipline — sorted by start time, any two spans are disjoint or
    properly nested (a track is one thread of execution, so overlap
    means clock or pairing corruption). Returns summary counts:
    ``{"events", "spans", "tracks", "names"}``.
    """
    if not isinstance(trace, dict) or \
            not isinstance(trace.get("traceEvents"), list):
        raise ValueError("trace must be a dict with a traceEvents list")
    spans_by_track: Dict[tuple, List[tuple]] = {}
    names = set()
    n_spans = 0
    for i, ev in enumerate(trace["traceEvents"]):
        for field in ("name", "ph", "pid", "tid"):
            if field not in ev:
                raise ValueError(f"event {i} missing {field!r}: {ev}")
        if ev["ph"] == "M":
            continue
        if "ts" not in ev:
            raise ValueError(f"event {i} missing 'ts': {ev}")
        if ev["ts"] < 0:
            raise ValueError(f"event {i} has negative ts: {ev}")
        names.add(ev["name"])
        if ev["ph"] == "X":
            if ev.get("dur", -1) < 0:
                raise ValueError(f"X event {i} needs dur >= 0: {ev}")
            n_spans += 1
            spans_by_track.setdefault((ev["pid"], ev["tid"]), []).append(
                (float(ev["ts"]), float(ev["ts"]) + float(ev["dur"]),
                 ev["name"]))
    for track, spans in spans_by_track.items():
        spans.sort()
        stack: List[tuple] = []
        for t0, t1, name in spans:
            while stack and t0 >= stack[-1][1]:
                stack.pop()
            if stack and t1 > stack[-1][1]:
                raise ValueError(
                    f"track {track}: span {name!r} [{t0}, {t1}] overlaps "
                    f"{stack[-1][2]!r} [{stack[-1][0]}, {stack[-1][1]}] "
                    f"without nesting")
            stack.append((t0, t1, name))
    return {"events": sum(1 for ev in trace["traceEvents"]
                          if ev["ph"] != "M"),
            "spans": n_spans,
            "tracks": len(spans_by_track),
            "names": sorted(names)}
