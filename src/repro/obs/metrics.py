"""Unified metrics registry: counters, gauges, histograms, one snapshot.

Before this module the serve stack's metrics were ad-hoc dicts scattered
across ``server.py``/``admission.py``/``cache.py``, each with its own
bespoke report plumbing. The registry replaces that with the standard
three instrument kinds (DESIGN.md §13):

- **Counter** — monotone accumulator (``serve_frames_total``). Floats
  allowed (``serve_render_seconds_total`` accumulates wall seconds).
- **Gauge** — last-written value (``scene_residency_padded_bytes``);
  ``set_max`` keeps a running maximum (``serve_max_concurrent_streams``).
- **Histogram** — lifetime ``count``/``sum``/``min``/``max`` plus a
  bounded newest-``keep`` reservoir for percentiles
  (``device_sort_pairs``). An empty histogram reports ``None``
  percentiles, never NaN — callers can snapshot before the first
  observation.

Metrics are keyed by ``(name, labels)`` — ``labels`` is a dict frozen
into the key, giving Prometheus-style families (one
``serve_frames_total`` per scene bucket). ``snapshot()`` returns one
plain-types dict (JSON-safe; ``StreamServer.report`` composes it) and
``to_prometheus()`` renders the text exposition format for scraping
(histograms as summaries with reservoir quantiles).

Thread safety: one registry lock guards creation, mutation, and export.
Every operation is O(1) dict/deque work — host-side nanoseconds next to
a serve round's milliseconds.
"""
from __future__ import annotations

import math
import re
import threading
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    name = _NAME_RE.sub("_", name)
    return name if not name[:1].isdigit() else "_" + name


def _label_str(labels: Tuple[Tuple[str, str], ...]) -> str:
    if not labels:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in labels) + "}"


class _Metric:
    """Shared identity: name + frozen labels (sorted key-value pairs)."""

    __slots__ = ("name", "labels", "help", "_lock")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...],
                 help: str, lock: threading.Lock):
        self.name = name
        self.labels = labels
        self.help = help
        self._lock = lock

    @property
    def key(self) -> str:
        return self.name + _label_str(self.labels)


class Counter(_Metric):
    """Monotone accumulator; ``inc`` rejects negative deltas."""

    __slots__ = ("_value",)

    def __init__(self, *a):
        super().__init__(*a)
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter {self.key} cannot decrease ({n})")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        return self._value


class Gauge(_Metric):
    """Last-written value; ``set_max`` keeps a running maximum."""

    __slots__ = ("_value",)

    def __init__(self, *a):
        super().__init__(*a)
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def set_max(self, v: float) -> None:
        with self._lock:
            self._value = max(self._value, float(v))

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        return self._value


class Histogram(_Metric):
    """Lifetime count/sum/min/max + bounded newest-``keep`` reservoir.

    The exact aggregates are lifetime-accurate no matter how long the
    server runs; percentiles are over the newest ``keep`` observations
    (the same recency trade the serve latency reservoirs make). Empty
    histograms report ``None`` percentiles — never NaN, never raise.
    """

    __slots__ = ("keep", "count", "total", "vmin", "vmax", "_reservoir")

    def __init__(self, name, labels, help, lock, keep: int = 4096):
        super().__init__(name, labels, help, lock)
        if keep < 1:
            raise ValueError(f"keep must be >= 1, got {keep}")
        self.keep = int(keep)
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self._reservoir: Deque[float] = deque(maxlen=self.keep)

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.count += 1
            self.total += v
            self.vmin = min(self.vmin, v)
            self.vmax = max(self.vmax, v)
            self._reservoir.append(v)

    def observe_many(self, vs: Sequence[float]) -> None:
        arr = np.asarray(vs, dtype=np.float64).reshape(-1)
        if arr.size == 0:
            return
        with self._lock:
            self.count += int(arr.size)
            self.total += float(arr.sum())
            self.vmin = min(self.vmin, float(arr.min()))
            self.vmax = max(self.vmax, float(arr.max()))
            self._reservoir.extend(arr.tolist())

    def values(self) -> List[float]:
        """Snapshot of the reservoir (newest ``keep`` observations)."""
        with self._lock:
            return list(self._reservoir)

    def percentile(self, q: float) -> Optional[float]:
        """Reservoir percentile, or None when nothing has been observed."""
        with self._lock:
            if not self._reservoir:
                return None
            return float(np.percentile(np.asarray(self._reservoir), q))

    def stats(self) -> dict:
        with self._lock:
            res = np.asarray(self._reservoir) if self._reservoir else None
            out = {
                "count": self.count,
                "sum": round(self.total, 6),
                "min": None if self.count == 0 else self.vmin,
                "max": None if self.count == 0 else self.vmax,
                "kept": 0 if res is None else int(res.size),
            }
        for q in (50, 90, 99):
            out[f"p{q}"] = None if res is None \
                else round(float(np.percentile(res, q)), 6)
        return out


class MetricsRegistry:
    """Get-or-create registry over the three instrument kinds.

    ``counter``/``gauge``/``histogram`` return the existing instrument
    for a ``(name, labels)`` pair (raising if it was registered as a
    different kind), so call sites never coordinate creation.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[Tuple[str, Tuple[Tuple[str, str], ...]],
                            _Metric] = {}

    def _get(self, cls, name: str, help: str, labels: dict, **kwargs):
        key = (str(name), tuple(sorted((str(k), str(v))
                                       for k, v in labels.items())))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(key[0], key[1], help, self._lock, **kwargs)
                self._metrics[key] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {m.key} already registered as "
                    f"{type(m).__name__}, requested {cls.__name__}")
            return m

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "", keep: int = 4096,
                  **labels) -> Histogram:
        return self._get(Histogram, name, help, labels, keep=keep)

    def _by_kind(self):
        with self._lock:
            metrics = list(self._metrics.values())
        counters = [m for m in metrics if isinstance(m, Counter)]
        gauges = [m for m in metrics if isinstance(m, Gauge)]
        hists = [m for m in metrics if isinstance(m, Histogram)]
        return counters, gauges, hists

    def snapshot(self) -> dict:
        """One JSON-safe dict over every registered instrument.

        Counters/gauges map ``key -> value`` (ints stay ints);
        histograms map ``key -> {count, sum, min, max, p50, p90, p99,
        kept}`` with None (not NaN) percentiles when empty.
        """
        counters, gauges, hists = self._by_kind()

        def num(v: float):
            return int(v) if float(v).is_integer() else round(v, 6)

        return {
            "counters": {m.key: num(m.value) for m in counters},
            "gauges": {m.key: num(m.value) for m in gauges},
            "histograms": {m.key: m.stats() for m in hists},
        }

    def to_prometheus(self) -> str:
        """Prometheus text exposition: counters/gauges verbatim,
        histograms as summaries (reservoir quantiles + lifetime
        ``_sum``/``_count``)."""
        counters, gauges, hists = self._by_kind()
        lines: List[str] = []
        seen_header = set()

        def header(name: str, kind: str, help: str):
            if name in seen_header:
                return
            seen_header.add(name)
            if help:
                lines.append(f"# HELP {name} {help}")
            lines.append(f"# TYPE {name} {kind}")

        for m in counters:
            name = _prom_name(m.name)
            header(name, "counter", m.help)
            lines.append(f"{name}{_label_str(m.labels)} {m.value:g}")
        for m in gauges:
            name = _prom_name(m.name)
            header(name, "gauge", m.help)
            lines.append(f"{name}{_label_str(m.labels)} {m.value:g}")
        for m in hists:
            name = _prom_name(m.name)
            header(name, "summary", m.help)
            for q in (0.5, 0.9, 0.99):
                v = m.percentile(100.0 * q)
                if v is not None:
                    labels = m.labels + (("quantile", f"{q:g}"),)
                    lines.append(f"{name}{_label_str(labels)} {v:g}")
            lines.append(f"{name}_sum{_label_str(m.labels)} {m.total:g}")
            lines.append(f"{name}_count{_label_str(m.labels)} {m.count}")
        return "\n".join(lines) + "\n"
