"""Observability layer (DESIGN.md §13): tracing + unified metrics.

``trace`` — span-based host tracing with Chrome-trace/Perfetto JSON
export, plus :func:`annotate` for naming jitted stages so XLA-level
profiles line up with the host spans. ``metrics`` — the
counter/gauge/histogram registry whose ``snapshot()`` the serve report
composes (and whose ``to_prometheus()`` a scraper can poll).

Both are deliberately dependency-free (jax + numpy only) so every layer
of the stack — kernels, core, serve, benchmarks — can use them without
import cycles.
"""
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.trace import (NULL_TRACER, Tracer, annotate,
                             validate_chrome_trace)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "NULL_TRACER",
    "Tracer", "annotate", "validate_chrome_trace",
]
